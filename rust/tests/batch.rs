//! Microbatched-scoring integration tests (no artifacts required): the
//! dedup + `--score-batch` dispatch pipeline, the lane-stacked scorer
//! scheduler and the slab cache must change *dispatch/upload counts only*
//! — the search archive stays byte-identical across every
//! `(workers, score-batch, lanes, slab-cache)` combination, and the shared
//! device bank's bytes (pieces + resident slabs) are counted once no
//! matter how many shards reference them.

use amq::coordinator::{
    run_search, slab_budget_bytes, Archive, BankShareStats, Config, ConfigEvaluator, EvalPool,
    PooledEvaluator, ProxyBank, SearchParams, SearchSpace,
};
use amq::data::Manifest;
use amq::quant::{MethodId, Quantizer};
use amq::runtime::{
    lane_dispatch_count, lane_padding, lane_routed, lane_slab_sig, planned_scorer_variant,
    planned_slab_gather, EvalService, ScorerVariant, SlabCache, SlabGatherMode,
};
use amq::tensor::Mat;
use amq::util::Rng;
use std::collections::HashSet;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

fn toy_space(n: usize) -> SearchSpace {
    SearchSpace {
        choices: vec![vec![2, 3, 4]; n],
        params: vec![128 * 128; n],
        groups: vec![128; n],
        group_size: 128,
    }
}

/// Deterministic synthetic "true evaluation", seeded purely from the
/// payload (the pool determinism contract).
fn synth_jsd(cfg: &Config) -> f32 {
    let mut seed = 0x9E37_79B9_7F4A_7C15u64;
    for &g in cfg {
        seed = seed.wrapping_mul(0x1000_0000_01B3).wrapping_add(g as u64);
    }
    let mut rng = Rng::new(seed);
    let base: f32 = cfg
        .iter()
        .enumerate()
        .map(|(i, &g)| {
            let w = if i % 5 == 0 { 1.0 } else { 0.04 };
            w * ((4 - g) as f32).powi(2)
        })
        .sum();
    base + rng.f32() * 1e-4
}

fn pooled(workers: usize, score_batch: usize) -> PooledEvaluator {
    PooledEvaluator::spawn(workers, |_shard| {
        |cfg: Config| -> amq::Result<f32> { Ok(synth_jsd(&cfg)) }
    })
    .with_score_batch(score_batch)
}

/// FNV-1a over the archive's full content — the reproducibility fingerprint.
fn archive_hash(archive: &Archive) -> u64 {
    let mut h = 0xCBF2_9CE4_8422_2325u64;
    let mut mix = |v: u64| {
        h ^= v;
        h = h.wrapping_mul(0x1000_0000_01B3);
    };
    for s in &archive.samples {
        for &g in &s.config {
            mix(g as u64);
        }
        mix(s.jsd.to_bits() as u64);
        mix(s.avg_bits.to_bits());
    }
    h
}

#[test]
fn archive_identical_across_workers_and_score_batch() {
    let space = toy_space(14);
    let mut params = SearchParams::smoke();
    params.seed = 29;

    // sequential trait-default baseline
    struct Seq(usize);
    impl ConfigEvaluator for Seq {
        fn eval_jsd(&mut self, config: &Config) -> amq::Result<f32> {
            self.0 += 1;
            Ok(synth_jsd(config))
        }
        fn count(&self) -> usize {
            self.0
        }
    }
    let baseline = run_search(&space, &mut Seq(0), &params).unwrap();
    let expect = archive_hash(&baseline.archive);

    for workers in [1usize, 4] {
        for score_batch in [1usize, 8] {
            let mut ev = pooled(workers, score_batch);
            let res = run_search(&space, &mut ev, &params).unwrap();
            assert_eq!(
                archive_hash(&res.archive),
                expect,
                "archive diverged at workers={workers} score_batch={score_batch}"
            );
            assert_eq!(
                res.true_evals, baseline.true_evals,
                "eval count diverged at workers={workers} score_batch={score_batch}"
            );
            assert_eq!(res.predictor_queries, baseline.predictor_queries);
        }
    }
}

#[test]
fn microbatching_cuts_dispatches_without_changing_results() {
    let space = toy_space(10);
    let mut params = SearchParams::smoke();
    params.seed = 3;

    let mut k1 = pooled(2, 1);
    let a = run_search(&space, &mut k1, &params).unwrap();
    let mut k8 = pooled(2, 8);
    let b = run_search(&space, &mut k8, &params).unwrap();
    assert_eq!(archive_hash(&a.archive), archive_hash(&b.archive));

    let (s1, s8) = (k1.batch_stats().unwrap(), k8.batch_stats().unwrap());
    assert_eq!(s1.evaluated, s8.evaluated, "same configs must reach the scorer");
    assert_eq!(s1.evaluated as usize, a.true_evals);
    assert_eq!(s1.dispatches, s1.evaluated, "k=1 is one dispatch per config");
    assert!(
        s8.dispatches < s8.evaluated,
        "k=8 must pack chunks: {} dispatches for {} evals",
        s8.dispatches,
        s8.evaluated
    );
    // the acceptance direction: requested-per-dispatch must beat the
    // k=1 pipeline (which already banks the dedup savings alone), and no
    // chunk may carry more than k configs
    assert!(
        s8.dispatch_reduction() > s1.dispatch_reduction(),
        "batching added nothing: k=8 {:.3} vs k=1 {:.3}",
        s8.dispatch_reduction(),
        s1.dispatch_reduction()
    );
    assert!(s8.dispatches >= (s8.evaluated as usize).div_ceil(8) as u64);
    assert!(
        s1.dispatch_reduction() >= 1.0 / (1.0 - s1.dedup_fraction()).max(1e-9) * 0.999,
        "dedup savings not realized: {:.3} for dedup fraction {:.3}",
        s1.dispatch_reduction(),
        s1.dedup_fraction()
    );
}

#[test]
fn search_reuses_cache_across_generations() {
    // the dedup counters must actually see cross-batch traffic: replaying
    // the same candidate set twice costs zero extra dispatches
    let mut ev = pooled(2, 4);
    let configs: Vec<Config> = (0..12)
        .map(|i| (0..6).map(|j| [2u16, 3, 4][(i + j) % 3]).collect())
        .collect();
    let first = ev.eval_jsd_batch(&configs).unwrap();
    let d0 = ev.batch_stats().unwrap().dispatches;
    let second = ev.eval_jsd_batch(&configs).unwrap();
    let s = ev.batch_stats().unwrap();
    assert_eq!(first, second);
    assert_eq!(s.dispatches, d0, "cached batch must not dispatch");
    assert_eq!(s.cache_hits, configs.len() as u64);
}

/// Device-dispatch accounting of a simulated lane-stacked scorer: the shard
/// closure mirrors `Runtime::scores_chunk`'s lane scheduler — one "device
/// dispatch" per group of up to `lanes` candidates, lane-0 padding on the
/// tail — while producing exactly the per-candidate `synth_jsd` results.
struct LaneCounters {
    dispatches: AtomicU64,
    padded: AtomicU64,
}

fn lane_pooled(
    workers: usize,
    score_batch: usize,
    lanes: usize,
) -> (PooledEvaluator, Arc<LaneCounters>) {
    let counters = Arc::new(LaneCounters {
        dispatches: AtomicU64::new(0),
        padded: AtomicU64::new(0),
    });
    let shared = counters.clone();
    let svc: Arc<EvalPool> = Arc::new(EvalService::spawn_sharded(workers, move |_shard| {
        let counters = shared.clone();
        move |chunk: Vec<Config>| -> amq::Result<Vec<f32>> {
            // production routing (the shared `lane_routed` predicate):
            // single-candidate chunks take the per-candidate path
            // (1 dispatch, no lane padding) even when the lane executable
            // is loaded
            let (dispatches, padded) = if lane_routed(chunk.len(), lanes) {
                (lane_dispatch_count(chunk.len(), lanes), lane_padding(chunk.len(), lanes))
            } else {
                (chunk.len(), 0)
            };
            counters.dispatches.fetch_add(dispatches as u64, Ordering::Relaxed);
            counters.padded.fetch_add(padded as u64, Ordering::Relaxed);
            Ok(chunk.iter().map(synth_jsd).collect())
        }
    }));
    (
        PooledEvaluator::from_service(svc).with_score_batch(score_batch),
        counters,
    )
}

#[test]
fn archive_identical_across_lane_widths() {
    // {lanes 1, lanes 8} x {workers 1, 4}: the scorer variant may only
    // change device-dispatch counts, never the archive
    let space = toy_space(12);
    let mut params = SearchParams::smoke();
    params.seed = 41;

    struct Seq(usize);
    impl ConfigEvaluator for Seq {
        fn eval_jsd(&mut self, config: &Config) -> amq::Result<f32> {
            self.0 += 1;
            Ok(synth_jsd(config))
        }
        fn count(&self) -> usize {
            self.0
        }
    }
    let baseline = run_search(&space, &mut Seq(0), &params).unwrap();
    let expect = archive_hash(&baseline.archive);

    let mut dispatches_by_lanes = Vec::new();
    for lanes in [1usize, 8] {
        for workers in [1usize, 4] {
            let (mut ev, counters) = lane_pooled(workers, 8, lanes);
            let res = run_search(&space, &mut ev, &params).unwrap();
            assert_eq!(
                archive_hash(&res.archive),
                expect,
                "archive diverged at lanes={lanes} workers={workers}"
            );
            assert_eq!(res.true_evals, baseline.true_evals);
            if workers == 1 {
                dispatches_by_lanes.push(counters.dispatches.load(Ordering::Relaxed));
            }
        }
    }
    // at 8 lanes every full chunk collapses into one device dispatch
    assert!(
        dispatches_by_lanes[1] < dispatches_by_lanes[0],
        "lane stacking saved no dispatches: x8 {} vs x1 {}",
        dispatches_by_lanes[1],
        dispatches_by_lanes[0]
    );
}

#[test]
fn partial_chunk_pads_with_lane_zero_and_discards() {
    // 13 unique candidates through an 8-lane scorer on one shard: the lone
    // 13-candidate chunk needs ceil(13/8) = 2 dispatches, the second one
    // padded with 3 copies of lane 0 whose outputs never surface
    let lanes = 8;
    let (mut ev, counters) = lane_pooled(1, 16, lanes);
    let configs: Vec<Config> = (0..13)
        .map(|i| (0..6).map(|j| [2u16, 3, 4][(i + j) % 3]).collect())
        .collect();
    let got = ev.eval_jsd_batch(&configs).unwrap();
    let want: Vec<f32> = configs.iter().map(synth_jsd).collect();
    assert_eq!(got, want, "padding must be invisible in the results");
    assert_eq!(counters.dispatches.load(Ordering::Relaxed), 2);
    assert_eq!(counters.padded.load(Ordering::Relaxed), 3);
    assert_eq!(lane_padding(13, lanes), 3);
}

#[test]
fn chunk_within_lane_width_is_one_dispatch() {
    // the acceptance pin: a chunk of K <= L candidates costs exactly one
    // scorer dispatch — lane-stacked for K > 1, per-candidate (resident
    // buffers, zero padding) for the K = 1 fast path
    let lanes = 8;
    for k in [1usize, 3, 8] {
        let (mut ev, counters) = lane_pooled(1, 8, lanes);
        let configs: Vec<Config> = (0..k)
            .map(|i| (0..5).map(|j| [2u16, 3, 4][(i + 2 * j) % 3]).collect())
            .collect();
        ev.eval_jsd_batch(&configs).unwrap();
        assert_eq!(
            counters.dispatches.load(Ordering::Relaxed),
            1,
            "chunk of {k} <= {lanes} candidates must be a single dispatch"
        );
        let expect_padded = if k > 1 { (lanes - k) as u64 } else { 0 };
        assert_eq!(counters.padded.load(Ordering::Relaxed), expect_padded);
    }
}

#[test]
fn manifest_without_lane_artifact_falls_back_per_candidate() {
    let base = r#"{
        "model": {"vocab_size": 512, "d_model": 128, "n_layers": 1,
                  "n_heads": 4, "d_ff": 256, "seq_len": 128,
                  "rope_theta": 10000.0, "rms_eps": 1e-5},
        "group_size": 128, "bit_choices": [2,3,4], "eval_batch": 16,
        "layers": [{"name": "blk0.q", "out_features": 128, "in_features": 128}],
        "fp_side_names": ["embed"],
        "executables": {EXECS}, "files": {}
    }"#;
    // legacy manifest: no lane executable -> per-candidate loop, and the
    // stats-facing variant says so
    let legacy = Manifest::from_json(&base.replace("{EXECS}", "{}")).unwrap();
    assert_eq!(legacy.scorer_lanes(), None);
    let v = planned_scorer_variant(&legacy, 0).unwrap();
    assert_eq!(v, ScorerVariant::PerCandidate);
    assert_eq!(v.name(), "per-candidate");
    assert_eq!(v.lanes(), 1);
    // asking for lanes the artifacts cannot serve is a hard error, not a
    // silent fallback
    assert!(planned_scorer_variant(&legacy, 8).is_err());

    // lane manifest: auto uses it, --lanes 1 opts out
    let lanes_exec = r#"{
        "scores_quant_lanes": {"file": "scores_quant_lanes8.hlo.txt",
                               "args": ["tokens"], "outputs": ["jsd", "ce"],
                               "lanes": 8}}"#;
    let lane = Manifest::from_json(&base.replace("{EXECS}", lanes_exec)).unwrap();
    assert_eq!(lane.scorer_lanes(), Some(8));
    let v = planned_scorer_variant(&lane, 0).unwrap();
    assert_eq!(v, ScorerVariant::LaneStacked { lanes: 8 });
    assert_eq!(v.name(), "lane-stacked");
    assert_eq!(v.lanes(), 8);
    assert_eq!(
        planned_scorer_variant(&lane, 1).unwrap(),
        ScorerVariant::PerCandidate
    );
    assert!(planned_scorer_variant(&lane, 4).is_err());
}

// ---------------------------------------------------------------------------
// Slab-cache matrix: archive identity, upload accounting, eviction safety
// ---------------------------------------------------------------------------

/// Simulated slab byte size (one size fits the toy geometry).
const SLAB_BYTES: usize = 1 << 14;

struct SlabCounters {
    /// Slab lookups issued by plan building (hits + misses).
    resolutions: AtomicU64,
    /// Host slab pack+upload events (cache misses on the host-pack route).
    uploads: AtomicU64,
    /// Device gather dispatches (cache misses on the gather route).
    gathers: AtomicU64,
    /// Bytes the gather route kept off the host→device upload path.
    bytes_avoided: AtomicU64,
    /// Distinct slab keys ever resolved.
    distinct: Mutex<HashSet<(usize, Vec<u16>)>>,
    /// Device dispatches (lane groups × batches on the lane path).
    dispatches: AtomicU64,
}

/// Pool whose shard closure simulates the production lane scheduler
/// *through the slabs*: per chunk, a plan resolves each group's per-layer
/// slab via the shared [`SlabCache`] (payload = the padded lane signature,
/// exactly what the packed bytes encode) and is then replayed across
/// `batches` calibration batches.  Candidate scores are reconstructed from
/// the **slab contents**, so a stale or miskeyed cache entry corrupts the
/// archive — cache transparency is load-bearing, not asserted on the side.
///
/// `gather` mirrors `DeviceProxy::plan_lane_chunk`'s miss routing: a cache
/// miss becomes a device gather over resident bank pieces (no host upload,
/// bytes accounted as avoided) instead of a host pack+upload.  Both routes
/// build the identical slab payload, as production does bitwise.
fn slab_pooled(
    workers: usize,
    score_batch: usize,
    lanes: usize,
    slab_budget: usize,
    batches: usize,
    n_layers: usize,
    gather: bool,
) -> (PooledEvaluator, Arc<SlabCounters>) {
    let counters = Arc::new(SlabCounters {
        resolutions: AtomicU64::new(0),
        uploads: AtomicU64::new(0),
        gathers: AtomicU64::new(0),
        bytes_avoided: AtomicU64::new(0),
        distinct: Mutex::new(HashSet::new()),
        dispatches: AtomicU64::new(0),
    });
    let cache: Arc<SlabCache<Vec<u16>>> = Arc::new(SlabCache::new(slab_budget));
    let shared = counters.clone();
    let svc: Arc<EvalPool> = Arc::new(EvalService::spawn_sharded(workers, move |_shard| {
        let counters = shared.clone();
        let cache = cache.clone();
        move |chunk: Vec<Config>| -> amq::Result<Vec<f32>> {
            if lane_routed(chunk.len(), lanes) {
                // plan once per chunk: resolve every group's layer slabs
                let mut plan: Vec<(usize, Vec<Arc<Vec<u16>>>)> = Vec::new();
                for group in chunk.chunks(lanes) {
                    let mut slabs = Vec::with_capacity(n_layers);
                    for li in 0..n_layers {
                        let sig = lane_slab_sig(group, li, lanes);
                        let key = (li, sig.clone());
                        counters.resolutions.fetch_add(1, Ordering::Relaxed);
                        let slab = cache.get_or_build(key.clone(), || {
                            if gather {
                                counters.gathers.fetch_add(1, Ordering::Relaxed);
                                counters
                                    .bytes_avoided
                                    .fetch_add(SLAB_BYTES as u64, Ordering::Relaxed);
                            } else {
                                counters.uploads.fetch_add(1, Ordering::Relaxed);
                            }
                            counters.distinct.lock().unwrap().insert(key.clone());
                            Ok((sig.clone(), SLAB_BYTES))
                        })?;
                        slabs.push(slab);
                    }
                    plan.push((group.len(), slabs));
                }
                // replay the pinned plan across every calibration batch:
                // zero uploads inside this loop, by construction
                let mut sums = vec![0.0f64; chunk.len()];
                for _ in 0..batches {
                    let mut idx = 0;
                    for (real, slabs) in &plan {
                        counters.dispatches.fetch_add(1, Ordering::Relaxed);
                        for j in 0..*real {
                            // the device reads the slab, not the candidate
                            let cfg: Config =
                                (0..n_layers).map(|li| slabs[li][j]).collect();
                            sums[idx] += synth_jsd(&cfg) as f64;
                            idx += 1;
                        }
                    }
                }
                Ok(sums.into_iter().map(|s| (s / batches as f64) as f32).collect())
            } else {
                // per-candidate path: resident buffers, no slabs
                let mut out = Vec::with_capacity(chunk.len());
                for cfg in &chunk {
                    counters.dispatches.fetch_add(batches as u64, Ordering::Relaxed);
                    let mut sum = 0.0f64;
                    for _ in 0..batches {
                        sum += synth_jsd(cfg) as f64;
                    }
                    out.push((sum / batches as f64) as f32);
                }
                Ok(out)
            }
        }
    }));
    (
        PooledEvaluator::from_service(svc).with_score_batch(score_batch),
        counters,
    )
}

#[test]
fn archive_identical_across_slab_cache_budgets() {
    // {slab-cache 0, 64 MB} x {lanes 1, 8}: the cache may only change
    // upload counts, never the archive — and because the simulated scores
    // flow *through* the cached slabs, a correctness bug here shows up as
    // an archive hash mismatch, not just a counter drift
    let n_layers = 12;
    let space = toy_space(n_layers);
    let mut params = SearchParams::smoke();
    params.seed = 53;

    struct Seq(usize);
    impl ConfigEvaluator for Seq {
        fn eval_jsd(&mut self, config: &Config) -> amq::Result<f32> {
            self.0 += 1;
            Ok(synth_jsd(config))
        }
        fn count(&self) -> usize {
            self.0
        }
    }
    let baseline = run_search(&space, &mut Seq(0), &params).unwrap();
    let expect = archive_hash(&baseline.archive);

    for lanes in [1usize, 8] {
        for budget_mb in [0usize, 64] {
            let (mut ev, counters) =
                slab_pooled(2, 8, lanes, slab_budget_bytes(budget_mb), 1, n_layers, false);
            let res = run_search(&space, &mut ev, &params).unwrap();
            assert_eq!(
                archive_hash(&res.archive),
                expect,
                "archive diverged at lanes={lanes} slab_cache={budget_mb}MB"
            );
            assert_eq!(res.true_evals, baseline.true_evals);
            let uploads = counters.uploads.load(Ordering::Relaxed);
            let distinct = counters.distinct.lock().unwrap().len() as u64;
            let resolutions = counters.resolutions.load(Ordering::Relaxed);
            if lanes == 1 {
                assert_eq!(uploads, 0, "per-candidate path must not pack slabs");
                assert_eq!(resolutions, 0);
            } else if budget_mb > 0 {
                // ample budget: exactly one upload per distinct slab
                assert_eq!(uploads, distinct, "cached run re-uploaded a resident slab");
            } else {
                // cache off: every lookup re-packs and re-uploads
                assert_eq!(uploads, resolutions, "budget 0 must re-pack per lookup");
                assert!(resolutions >= distinct);
            }
        }
    }
}

#[test]
fn multi_batch_uploads_count_distinct_slabs_not_batches() {
    // the acceptance pin: with B calibration batches, slab uploads scale
    // with *distinct slabs*, never with slabs × batches — the plan is
    // resolved once per chunk and replayed, and the cache carries slabs
    // across chunks and generations
    let n_layers = 10;
    let configs: Vec<Config> = (0..24)
        .map(|i| (0..n_layers).map(|j| [2u16, 3, 4][(i + j) % 3]).collect())
        .collect();
    let mut counts = Vec::new();
    for batches in [1usize, 3] {
        let (mut ev, counters) =
            slab_pooled(1, 8, 8, slab_budget_bytes(64), batches, n_layers, false);
        // two identical generations: the second is pure cache traffic at
        // the evaluator level, so no new slab work at all
        let first = ev.eval_jsd_batch(&configs).unwrap();
        let second = ev.eval_jsd_batch(&configs).unwrap();
        assert_eq!(first, second);
        let uploads = counters.uploads.load(Ordering::Relaxed);
        let distinct = counters.distinct.lock().unwrap().len() as u64;
        assert_eq!(
            uploads, distinct,
            "uploads must equal distinct slabs at {batches} batches"
        );
        // dispatches do scale with batches (that is the scoring work)...
        let groups: u64 = configs
            .chunks(8)
            .map(|c| lane_dispatch_count(c.len(), 8) as u64)
            .sum();
        assert_eq!(
            counters.dispatches.load(Ordering::Relaxed),
            groups * batches as u64
        );
        counts.push(uploads);
    }
    // ...but uploads are batch-count invariant
    assert_eq!(counts[0], counts[1], "slab uploads scaled with batches");
}

#[test]
fn eviction_under_tiny_budget_still_scores_correctly() {
    // a budget holding exactly one slab churns constantly; scores must
    // stay identical to the uncached baseline, and uploads must still not
    // scale with the calibration-batch count (plans pin their slabs)
    let n_layers = 6;
    let configs: Vec<Config> = (0..16)
        .map(|i| (0..n_layers).map(|j| [2u16, 3, 4][(i + 2 * j) % 3]).collect())
        .collect();
    let want: Vec<f32> = configs.iter().map(synth_jsd).collect();
    let mut uploads_by_batches = Vec::new();
    for batches in [1usize, 3] {
        let (mut ev, counters) = slab_pooled(1, 8, 8, SLAB_BYTES, batches, n_layers, false);
        let got = ev.eval_jsd_batch(&configs).unwrap();
        assert_eq!(got, want, "eviction changed scores at {batches} batches");
        uploads_by_batches.push(counters.uploads.load(Ordering::Relaxed));
        let distinct = counters.distinct.lock().unwrap().len() as u64;
        assert!(
            counters.uploads.load(Ordering::Relaxed) >= distinct,
            "thrashing cache cannot beat one upload per distinct slab"
        );
    }
    assert_eq!(
        uploads_by_batches[0], uploads_by_batches[1],
        "pinned plans must keep uploads batch-invariant even while evicting"
    );
}

// ---------------------------------------------------------------------------
// Device-side slab gather: upload accounting, archive transparency, fallback
// ---------------------------------------------------------------------------

#[test]
fn gather_route_does_zero_host_uploads() {
    // the acceptance pin: a cold multi-batch search with the gather
    // artifact does zero host slab uploads — every miss is a device gather
    // over resident bank pieces, and the bytes avoided are exactly what
    // the host-pack route would have uploaded (one slab per distinct key)
    let n_layers = 12;
    let space = toy_space(n_layers);
    let mut params = SearchParams::smoke();
    params.seed = 67;

    let (mut host, host_c) =
        slab_pooled(2, 8, 8, slab_budget_bytes(64), 3, n_layers, false);
    let host_res = run_search(&space, &mut host, &params).unwrap();
    let expect = archive_hash(&host_res.archive);
    assert!(host_c.uploads.load(Ordering::Relaxed) > 0);
    assert_eq!(host_c.gathers.load(Ordering::Relaxed), 0);

    for workers in [1usize, 4] {
        let (mut ev, c) = slab_pooled(workers, 8, 8, slab_budget_bytes(64), 3, n_layers, true);
        let res = run_search(&space, &mut ev, &params).unwrap();
        assert_eq!(
            archive_hash(&res.archive),
            expect,
            "gather route changed the archive at workers={workers}"
        );
        assert_eq!(
            c.uploads.load(Ordering::Relaxed),
            0,
            "gather run must not host-upload slabs"
        );
        let distinct = c.distinct.lock().unwrap().len() as u64;
        assert!(distinct > 0);
        assert_eq!(
            c.gathers.load(Ordering::Relaxed),
            distinct,
            "one device gather per distinct slab"
        );
        assert_eq!(
            c.bytes_avoided.load(Ordering::Relaxed),
            distinct * SLAB_BYTES as u64,
            "bytes avoided must equal the sum of the slab sizes"
        );
    }
}

#[test]
fn archive_identical_across_slab_gather_modes() {
    // {gather off, auto-with-artifact} x {lanes 1, 8} x {workers 1, 4}:
    // the miss route may only change upload/gather counters, never the
    // archive — scores flow through the slab contents on both routes
    let n_layers = 12;
    let space = toy_space(n_layers);
    let mut params = SearchParams::smoke();
    params.seed = 71;

    struct Seq(usize);
    impl ConfigEvaluator for Seq {
        fn eval_jsd(&mut self, config: &Config) -> amq::Result<f32> {
            self.0 += 1;
            Ok(synth_jsd(config))
        }
        fn count(&self) -> usize {
            self.0
        }
    }
    let baseline = run_search(&space, &mut Seq(0), &params).unwrap();
    let expect = archive_hash(&baseline.archive);

    for gather in [false, true] {
        for lanes in [1usize, 8] {
            for workers in [1usize, 4] {
                let (mut ev, c) = slab_pooled(
                    workers,
                    8,
                    lanes,
                    slab_budget_bytes(64),
                    1,
                    n_layers,
                    gather,
                );
                let res = run_search(&space, &mut ev, &params).unwrap();
                assert_eq!(
                    archive_hash(&res.archive),
                    expect,
                    "archive diverged at gather={gather} lanes={lanes} workers={workers}"
                );
                assert_eq!(res.true_evals, baseline.true_evals);
                if lanes == 1 {
                    // per-candidate path: no slabs, so nothing to gather
                    assert_eq!(c.gathers.load(Ordering::Relaxed), 0);
                    assert_eq!(c.uploads.load(Ordering::Relaxed), 0);
                }
            }
        }
    }
}

#[test]
fn manifest_without_gather_artifact_falls_back_to_host_pack() {
    let base = r#"{
        "model": {"vocab_size": 512, "d_model": 128, "n_layers": 1,
                  "n_heads": 4, "d_ff": 256, "seq_len": 128,
                  "rope_theta": 10000.0, "rms_eps": 1e-5},
        "group_size": 128, "bit_choices": [2,3,4], "eval_batch": 16,
        "layers": [{"name": "blk0.q", "out_features": 128, "in_features": 128}],
        "fp_side_names": ["embed"],
        "executables": {EXECS}, "files": {}
    }"#;
    // lane scorer but no gather executables (legacy artifact): auto and
    // off fall back to host packing with no behavior change; require is a
    // hard error pointing at the rebuild knob
    let scorer_only = r#"{
        "scores_quant_lanes": {"file": "scores_quant_lanes2.hlo.txt",
                               "args": ["tokens"], "outputs": ["jsd", "ce"],
                               "lanes": 2}}"#;
    let legacy = Manifest::from_json(&base.replace("{EXECS}", scorer_only)).unwrap();
    assert_eq!(legacy.gather_lanes(), None);
    assert!(!planned_slab_gather(&legacy, 0, SlabGatherMode::Auto).unwrap());
    assert!(!planned_slab_gather(&legacy, 0, SlabGatherMode::Off).unwrap());
    let err = planned_slab_gather(&legacy, 0, SlabGatherMode::Require)
        .unwrap_err()
        .to_string();
    assert!(err.contains("AMQ_SLAB_GATHER=1"), "got: {err}");

    // gather executables present: auto (and require) route misses through
    // the device gather; off and --lanes 1 keep the host path
    let with_gather = r#"{
        "scores_quant_lanes": {"file": "scores_quant_lanes2.hlo.txt",
                               "args": ["tokens"], "outputs": ["jsd", "ce"],
                               "lanes": 2},
        "gather_lanes_128x128": {"file": "gather_lanes2_128x128.hlo.txt",
                                 "args": ["lane0.codes", "lane0.scale",
                                          "lane0.zero", "lane1.codes",
                                          "lane1.scale", "lane1.zero"],
                                 "outputs": ["codes", "scale", "zero"],
                                 "lanes": 2}}"#;
    let m = Manifest::from_json(&base.replace("{EXECS}", with_gather)).unwrap();
    assert_eq!(m.gather_lanes(), Some(2));
    assert!(planned_slab_gather(&m, 0, SlabGatherMode::Auto).unwrap());
    assert!(planned_slab_gather(&m, 2, SlabGatherMode::Require).unwrap());
    assert!(!planned_slab_gather(&m, 0, SlabGatherMode::Off).unwrap());
    assert!(!planned_slab_gather(&m, 1, SlabGatherMode::Auto).unwrap());
}

#[test]
fn shared_device_bank_bytes_count_once() {
    // a real (host-side) bank: 2 layers x 3 bits of quantized weights
    let quantizer = MethodId::Hqq.build();
    let pieces = vec![(0..2u64)
        .map(|i| {
            let mut rng = Rng::new(1 + i);
            let mut w = Mat::zeros(8, 128);
            for v in &mut w.data {
                *v = rng.normal() * 0.1;
            }
            vec![
                quantizer.quantize(&w, 2, 128, None),
                quantizer.quantize(&w, 3, 128, None),
                quantizer.quantize(&w, 4, 128, None),
            ]
        })
        .collect()];
    let bank =
        Arc::new(ProxyBank::from_parts(vec![MethodId::Hqq], vec![2, 3, 4], pieces).unwrap());
    let bytes = bank.memory_bytes();
    assert!(bytes > 0);

    // 4 pool shards all referencing the one Arc'd bank
    let shards: Vec<Arc<ProxyBank>> = (0..4).map(|_| bank.clone()).collect();
    let share = BankShareStats::from_shard_banks(&shards);
    assert_eq!(share.shards, 4);
    assert_eq!(share.resident_bytes, bytes, "shared bank must be counted once");
    assert_eq!(share.referenced_bytes, 4 * bytes);
}

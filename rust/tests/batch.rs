//! Microbatched-scoring integration tests (no artifacts required): the
//! dedup + `--score-batch` dispatch pipeline and the lane-stacked scorer
//! scheduler must change *dispatch counts only* — the search archive stays
//! byte-identical across every `(workers, score-batch, lanes)` combination,
//! and the shared device bank's bytes are counted once no matter how many
//! shards reference it.

use amq::coordinator::{
    run_search, Archive, BankShareStats, Config, ConfigEvaluator, EvalPool, PooledEvaluator,
    ProxyBank, SearchParams, SearchSpace,
};
use amq::data::Manifest;
use amq::quant::{MethodId, Quantizer};
use amq::runtime::{
    lane_dispatch_count, lane_padding, lane_routed, planned_scorer_variant, EvalService,
    ScorerVariant,
};
use amq::tensor::Mat;
use amq::util::Rng;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

fn toy_space(n: usize) -> SearchSpace {
    SearchSpace {
        choices: vec![vec![2, 3, 4]; n],
        params: vec![128 * 128; n],
        groups: vec![128; n],
        group_size: 128,
    }
}

/// Deterministic synthetic "true evaluation", seeded purely from the
/// payload (the pool determinism contract).
fn synth_jsd(cfg: &Config) -> f32 {
    let mut seed = 0x9E37_79B9_7F4A_7C15u64;
    for &g in cfg {
        seed = seed.wrapping_mul(0x1000_0000_01B3).wrapping_add(g as u64);
    }
    let mut rng = Rng::new(seed);
    let base: f32 = cfg
        .iter()
        .enumerate()
        .map(|(i, &g)| {
            let w = if i % 5 == 0 { 1.0 } else { 0.04 };
            w * ((4 - g) as f32).powi(2)
        })
        .sum();
    base + rng.f32() * 1e-4
}

fn pooled(workers: usize, score_batch: usize) -> PooledEvaluator {
    PooledEvaluator::spawn(workers, |_shard| {
        |cfg: Config| -> amq::Result<f32> { Ok(synth_jsd(&cfg)) }
    })
    .with_score_batch(score_batch)
}

/// FNV-1a over the archive's full content — the reproducibility fingerprint.
fn archive_hash(archive: &Archive) -> u64 {
    let mut h = 0xCBF2_9CE4_8422_2325u64;
    let mut mix = |v: u64| {
        h ^= v;
        h = h.wrapping_mul(0x1000_0000_01B3);
    };
    for s in &archive.samples {
        for &g in &s.config {
            mix(g as u64);
        }
        mix(s.jsd.to_bits() as u64);
        mix(s.avg_bits.to_bits());
    }
    h
}

#[test]
fn archive_identical_across_workers_and_score_batch() {
    let space = toy_space(14);
    let mut params = SearchParams::smoke();
    params.seed = 29;

    // sequential trait-default baseline
    struct Seq(usize);
    impl ConfigEvaluator for Seq {
        fn eval_jsd(&mut self, config: &Config) -> amq::Result<f32> {
            self.0 += 1;
            Ok(synth_jsd(config))
        }
        fn count(&self) -> usize {
            self.0
        }
    }
    let baseline = run_search(&space, &mut Seq(0), &params).unwrap();
    let expect = archive_hash(&baseline.archive);

    for workers in [1usize, 4] {
        for score_batch in [1usize, 8] {
            let mut ev = pooled(workers, score_batch);
            let res = run_search(&space, &mut ev, &params).unwrap();
            assert_eq!(
                archive_hash(&res.archive),
                expect,
                "archive diverged at workers={workers} score_batch={score_batch}"
            );
            assert_eq!(
                res.true_evals, baseline.true_evals,
                "eval count diverged at workers={workers} score_batch={score_batch}"
            );
            assert_eq!(res.predictor_queries, baseline.predictor_queries);
        }
    }
}

#[test]
fn microbatching_cuts_dispatches_without_changing_results() {
    let space = toy_space(10);
    let mut params = SearchParams::smoke();
    params.seed = 3;

    let mut k1 = pooled(2, 1);
    let a = run_search(&space, &mut k1, &params).unwrap();
    let mut k8 = pooled(2, 8);
    let b = run_search(&space, &mut k8, &params).unwrap();
    assert_eq!(archive_hash(&a.archive), archive_hash(&b.archive));

    let (s1, s8) = (k1.batch_stats().unwrap(), k8.batch_stats().unwrap());
    assert_eq!(s1.evaluated, s8.evaluated, "same configs must reach the scorer");
    assert_eq!(s1.evaluated as usize, a.true_evals);
    assert_eq!(s1.dispatches, s1.evaluated, "k=1 is one dispatch per config");
    assert!(
        s8.dispatches < s8.evaluated,
        "k=8 must pack chunks: {} dispatches for {} evals",
        s8.dispatches,
        s8.evaluated
    );
    // the acceptance direction: requested-per-dispatch must beat the
    // k=1 pipeline (which already banks the dedup savings alone), and no
    // chunk may carry more than k configs
    assert!(
        s8.dispatch_reduction() > s1.dispatch_reduction(),
        "batching added nothing: k=8 {:.3} vs k=1 {:.3}",
        s8.dispatch_reduction(),
        s1.dispatch_reduction()
    );
    assert!(s8.dispatches >= (s8.evaluated as usize).div_ceil(8) as u64);
    assert!(
        s1.dispatch_reduction() >= 1.0 / (1.0 - s1.dedup_fraction()).max(1e-9) * 0.999,
        "dedup savings not realized: {:.3} for dedup fraction {:.3}",
        s1.dispatch_reduction(),
        s1.dedup_fraction()
    );
}

#[test]
fn search_reuses_cache_across_generations() {
    // the dedup counters must actually see cross-batch traffic: replaying
    // the same candidate set twice costs zero extra dispatches
    let mut ev = pooled(2, 4);
    let configs: Vec<Config> = (0..12)
        .map(|i| (0..6).map(|j| [2u16, 3, 4][(i + j) % 3]).collect())
        .collect();
    let first = ev.eval_jsd_batch(&configs).unwrap();
    let d0 = ev.batch_stats().unwrap().dispatches;
    let second = ev.eval_jsd_batch(&configs).unwrap();
    let s = ev.batch_stats().unwrap();
    assert_eq!(first, second);
    assert_eq!(s.dispatches, d0, "cached batch must not dispatch");
    assert_eq!(s.cache_hits, configs.len() as u64);
}

/// Device-dispatch accounting of a simulated lane-stacked scorer: the shard
/// closure mirrors `Runtime::scores_chunk`'s lane scheduler — one "device
/// dispatch" per group of up to `lanes` candidates, lane-0 padding on the
/// tail — while producing exactly the per-candidate `synth_jsd` results.
struct LaneCounters {
    dispatches: AtomicU64,
    padded: AtomicU64,
}

fn lane_pooled(
    workers: usize,
    score_batch: usize,
    lanes: usize,
) -> (PooledEvaluator, Arc<LaneCounters>) {
    let counters = Arc::new(LaneCounters {
        dispatches: AtomicU64::new(0),
        padded: AtomicU64::new(0),
    });
    let shared = counters.clone();
    let svc: Arc<EvalPool> = Arc::new(EvalService::spawn_sharded(workers, move |_shard| {
        let counters = shared.clone();
        move |chunk: Vec<Config>| -> amq::Result<Vec<f32>> {
            // production routing (the shared `lane_routed` predicate):
            // single-candidate chunks take the per-candidate path
            // (1 dispatch, no lane padding) even when the lane executable
            // is loaded
            let (dispatches, padded) = if lane_routed(chunk.len(), lanes) {
                (lane_dispatch_count(chunk.len(), lanes), lane_padding(chunk.len(), lanes))
            } else {
                (chunk.len(), 0)
            };
            counters.dispatches.fetch_add(dispatches as u64, Ordering::Relaxed);
            counters.padded.fetch_add(padded as u64, Ordering::Relaxed);
            Ok(chunk.iter().map(synth_jsd).collect())
        }
    }));
    (
        PooledEvaluator::from_service(svc).with_score_batch(score_batch),
        counters,
    )
}

#[test]
fn archive_identical_across_lane_widths() {
    // {lanes 1, lanes 8} x {workers 1, 4}: the scorer variant may only
    // change device-dispatch counts, never the archive
    let space = toy_space(12);
    let mut params = SearchParams::smoke();
    params.seed = 41;

    struct Seq(usize);
    impl ConfigEvaluator for Seq {
        fn eval_jsd(&mut self, config: &Config) -> amq::Result<f32> {
            self.0 += 1;
            Ok(synth_jsd(config))
        }
        fn count(&self) -> usize {
            self.0
        }
    }
    let baseline = run_search(&space, &mut Seq(0), &params).unwrap();
    let expect = archive_hash(&baseline.archive);

    let mut dispatches_by_lanes = Vec::new();
    for lanes in [1usize, 8] {
        for workers in [1usize, 4] {
            let (mut ev, counters) = lane_pooled(workers, 8, lanes);
            let res = run_search(&space, &mut ev, &params).unwrap();
            assert_eq!(
                archive_hash(&res.archive),
                expect,
                "archive diverged at lanes={lanes} workers={workers}"
            );
            assert_eq!(res.true_evals, baseline.true_evals);
            if workers == 1 {
                dispatches_by_lanes.push(counters.dispatches.load(Ordering::Relaxed));
            }
        }
    }
    // at 8 lanes every full chunk collapses into one device dispatch
    assert!(
        dispatches_by_lanes[1] < dispatches_by_lanes[0],
        "lane stacking saved no dispatches: x8 {} vs x1 {}",
        dispatches_by_lanes[1],
        dispatches_by_lanes[0]
    );
}

#[test]
fn partial_chunk_pads_with_lane_zero_and_discards() {
    // 13 unique candidates through an 8-lane scorer on one shard: the lone
    // 13-candidate chunk needs ceil(13/8) = 2 dispatches, the second one
    // padded with 3 copies of lane 0 whose outputs never surface
    let lanes = 8;
    let (mut ev, counters) = lane_pooled(1, 16, lanes);
    let configs: Vec<Config> = (0..13)
        .map(|i| (0..6).map(|j| [2u16, 3, 4][(i + j) % 3]).collect())
        .collect();
    let got = ev.eval_jsd_batch(&configs).unwrap();
    let want: Vec<f32> = configs.iter().map(synth_jsd).collect();
    assert_eq!(got, want, "padding must be invisible in the results");
    assert_eq!(counters.dispatches.load(Ordering::Relaxed), 2);
    assert_eq!(counters.padded.load(Ordering::Relaxed), 3);
    assert_eq!(lane_padding(13, lanes), 3);
}

#[test]
fn chunk_within_lane_width_is_one_dispatch() {
    // the acceptance pin: a chunk of K <= L candidates costs exactly one
    // scorer dispatch — lane-stacked for K > 1, per-candidate (resident
    // buffers, zero padding) for the K = 1 fast path
    let lanes = 8;
    for k in [1usize, 3, 8] {
        let (mut ev, counters) = lane_pooled(1, 8, lanes);
        let configs: Vec<Config> = (0..k)
            .map(|i| (0..5).map(|j| [2u16, 3, 4][(i + 2 * j) % 3]).collect())
            .collect();
        ev.eval_jsd_batch(&configs).unwrap();
        assert_eq!(
            counters.dispatches.load(Ordering::Relaxed),
            1,
            "chunk of {k} <= {lanes} candidates must be a single dispatch"
        );
        let expect_padded = if k > 1 { (lanes - k) as u64 } else { 0 };
        assert_eq!(counters.padded.load(Ordering::Relaxed), expect_padded);
    }
}

#[test]
fn manifest_without_lane_artifact_falls_back_per_candidate() {
    let base = r#"{
        "model": {"vocab_size": 512, "d_model": 128, "n_layers": 1,
                  "n_heads": 4, "d_ff": 256, "seq_len": 128,
                  "rope_theta": 10000.0, "rms_eps": 1e-5},
        "group_size": 128, "bit_choices": [2,3,4], "eval_batch": 16,
        "layers": [{"name": "blk0.q", "out_features": 128, "in_features": 128}],
        "fp_side_names": ["embed"],
        "executables": {EXECS}, "files": {}
    }"#;
    // legacy manifest: no lane executable -> per-candidate loop, and the
    // stats-facing variant says so
    let legacy = Manifest::from_json(&base.replace("{EXECS}", "{}")).unwrap();
    assert_eq!(legacy.scorer_lanes(), None);
    let v = planned_scorer_variant(&legacy, 0).unwrap();
    assert_eq!(v, ScorerVariant::PerCandidate);
    assert_eq!(v.name(), "per-candidate");
    assert_eq!(v.lanes(), 1);
    // asking for lanes the artifacts cannot serve is a hard error, not a
    // silent fallback
    assert!(planned_scorer_variant(&legacy, 8).is_err());

    // lane manifest: auto uses it, --lanes 1 opts out
    let lanes_exec = r#"{
        "scores_quant_lanes": {"file": "scores_quant_lanes8.hlo.txt",
                               "args": ["tokens"], "outputs": ["jsd", "ce"],
                               "lanes": 8}}"#;
    let lane = Manifest::from_json(&base.replace("{EXECS}", lanes_exec)).unwrap();
    assert_eq!(lane.scorer_lanes(), Some(8));
    let v = planned_scorer_variant(&lane, 0).unwrap();
    assert_eq!(v, ScorerVariant::LaneStacked { lanes: 8 });
    assert_eq!(v.name(), "lane-stacked");
    assert_eq!(v.lanes(), 8);
    assert_eq!(
        planned_scorer_variant(&lane, 1).unwrap(),
        ScorerVariant::PerCandidate
    );
    assert!(planned_scorer_variant(&lane, 4).is_err());
}

#[test]
fn shared_device_bank_bytes_count_once() {
    // a real (host-side) bank: 2 layers x 3 bits of quantized weights
    let quantizer = MethodId::Hqq.build();
    let pieces = vec![(0..2u64)
        .map(|i| {
            let mut rng = Rng::new(1 + i);
            let mut w = Mat::zeros(8, 128);
            for v in &mut w.data {
                *v = rng.normal() * 0.1;
            }
            vec![
                quantizer.quantize(&w, 2, 128, None),
                quantizer.quantize(&w, 3, 128, None),
                quantizer.quantize(&w, 4, 128, None),
            ]
        })
        .collect()];
    let bank =
        Arc::new(ProxyBank::from_parts(vec![MethodId::Hqq], vec![2, 3, 4], pieces).unwrap());
    let bytes = bank.memory_bytes();
    assert!(bytes > 0);

    // 4 pool shards all referencing the one Arc'd bank
    let shards: Vec<Arc<ProxyBank>> = (0..4).map(|_| bank.clone()).collect();
    let share = BankShareStats::from_shard_banks(&shards);
    assert_eq!(share.shards, 4);
    assert_eq!(share.resident_bytes, bytes, "shared bank must be counted once");
    assert_eq!(share.referenced_bytes, 4 * bytes);
}

//! Deterministic chaos matrix for the eval pool: seeded fault plans
//! ({delayed, wedged, crashed} shards) crossed with topologies
//! ({in-process, loopback TCP, mixed}) and hedging ({on, off}) must always
//! converge to the archive the fault-free sequential baseline produces —
//! faults and hedges perturb the transport and the schedule, never the
//! results.
//!
//! Every scenario is seeded and replayable: wedges block on a
//! [`FaultPlan`] gate until the test opens it, and delays / drops /
//! disconnects come from the plan's seeded decision stream — no
//! sleep-and-hope timing assertions.
//!
//! CI runs this suite single-threaded (`--test-threads=1`) so loopback
//! servers never contend for ports or CPU with sibling tests.

use amq::coordinator::synth::{synth_chunk, synth_space};
use amq::coordinator::{run_search, Config, EvalPool, PooledEvaluator, SearchParams};
use amq::runtime::remote::{
    remote_eval_flow_with_timeout, spawn_test_server, spawn_test_server_with_faults, RetryPolicy,
};
use amq::runtime::{
    EvalService, FaultKind, FaultPlan, FaultSpec, HedgePolicy, ServiceStats, ShardFlow,
};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn seeded_params() -> SearchParams {
    let mut p = SearchParams::smoke();
    p.seed = 17;
    p
}

/// Reconnect quickly so fault-recovery lanes converge in milliseconds
/// instead of the production backoff schedule.
fn fast_retry() -> RetryPolicy {
    RetryPolicy {
        attempts: 2,
        base_delay: Duration::from_millis(1),
        max_delay: Duration::from_millis(5),
    }
}

/// Run the seeded synthetic search against `svc` and report the archive
/// content hash plus the pool's view of how the work went.
fn search_hash(svc: &Arc<EvalPool>) -> (u64, ServiceStats) {
    let space = synth_space(12);
    let mut ev = PooledEvaluator::from_service(svc.clone()).with_score_batch(8);
    let res = run_search(&space, &mut ev, &seeded_params()).unwrap();
    (res.archive.content_hash(), ev.pool_stats())
}

/// The fault-free single-worker reference every chaos lane must reproduce.
fn baseline_hash() -> u64 {
    let svc: Arc<EvalPool> = Arc::new(EvalService::spawn_sharded(1, |_shard| {
        |chunk: Vec<Config>| -> amq::Result<Vec<f32>> { synth_chunk(&chunk) }
    }));
    search_hash(&svc).0
}

/// Four in-process shards; shard 0's flow is wrapped in `plan`, the rest
/// stay clean so the pool always has healthy capacity to converge on.
fn faulted_local_pool(plan: Arc<FaultPlan>, policy: HedgePolicy) -> Arc<EvalPool> {
    let labels: Vec<String> = (0..4).map(|i| format!("local#{i}")).collect();
    let builder = move |shard: usize| {
        let inner: Box<dyn FnMut(Vec<Config>) -> ShardFlow<amq::Result<Vec<f32>>>> =
            Box::new(move |chunk: Vec<Config>| ShardFlow::Reply(synth_chunk(&chunk)));
        if shard == 0 {
            plan.wrap_flow(inner)
        } else {
            inner
        }
    };
    Arc::new(EvalService::spawn_flow_with(labels, builder, policy))
}

/// `local` in-process shards plus one timeout-bounded feeder per remote
/// address — the wiring `repro search --shards --chunk-timeout-ms` builds.
fn mixed_pool(
    local: usize,
    remotes: Vec<String>,
    retry: RetryPolicy,
    chunk_timeout: Duration,
    policy: HedgePolicy,
) -> Arc<EvalPool> {
    let labels: Vec<String> = (0..local)
        .map(|i| format!("local#{i}"))
        .chain(remotes.iter().cloned())
        .collect();
    let builder = move |shard: usize| {
        if shard < local {
            Box::new(move |chunk: Vec<Config>| ShardFlow::Reply(synth_chunk(&chunk)))
        } else {
            remote_eval_flow_with_timeout(
                remotes[shard - local].clone(),
                retry,
                Some(chunk_timeout),
            )
        }
    };
    Arc::new(EvalService::spawn_flow_with(labels, builder, policy))
}

/// Copy conservation: every *resolved* chunk copy is exactly one of
/// {winning reply, discarded hedge duplicate, suppressed requeue
/// duplicate}.  This identity holds at every instant — copies still in
/// flight have not incremented `dispatched` yet.
fn assert_balanced(s: &ServiceStats) {
    assert_eq!(
        s.completed,
        s.dispatched - s.hedged_wasted - s.requeued_duplicates,
        "copy conservation violated: {s:?}"
    );
}

/// Wait (bounded) for every in-flight chunk copy to resolve — used after
/// opening a wedge gate, so post-release accounting is quiescent before
/// the service is dropped (its `Drop` joins the workers).
fn drain(svc: &Arc<EvalPool>) {
    let deadline = Instant::now() + Duration::from_secs(10);
    while svc.in_flight() > 0 {
        assert!(
            Instant::now() < deadline,
            "pool failed to drain after wedge release"
        );
        std::thread::sleep(Duration::from_millis(1));
    }
}

#[test]
fn delayed_shard_in_process_converges_with_and_without_hedging() {
    let baseline = baseline_hash();
    for factor in [0.0, 4.0] {
        let spec = FaultSpec { seed: 23, kind: FaultKind::Delay, rate: 1.0 };
        let plan = Arc::new(FaultPlan::new(spec).with_delay(Duration::from_millis(2)));
        let svc = faulted_local_pool(plan.clone(), HedgePolicy::from_factor(factor));
        let (hash, stats) = search_hash(&svc);
        assert_eq!(
            baseline, hash,
            "delayed shard diverged the archive (hedge factor {factor})"
        );
        assert_eq!(stats.requeued, 0, "a slow shard must never cause requeues");
        assert_eq!(stats.retired_shards(), 0);
        assert!(plan.injected() >= 1, "the seeded delay plan never fired");
        assert_balanced(&stats);
    }
}

#[test]
fn wedged_shard_in_process_is_won_by_a_hedge() {
    let baseline = baseline_hash();
    // Shard 0 wedges on its first chunk (rate 1.0, capped at one injection)
    // and holds it on the gate; hedging is the only recovery mechanism here
    // — in-process shards have no chunk timeout — so `hedged_won >= 1` is a
    // hard requirement, not a statistic.
    let spec = FaultSpec { seed: 7, kind: FaultKind::Wedge, rate: 1.0 };
    let plan = Arc::new(FaultPlan::new(spec).with_max_faults(1));
    let svc = faulted_local_pool(plan.clone(), HedgePolicy::from_factor(4.0));
    let (hash, stats) = search_hash(&svc);
    assert_eq!(baseline, hash, "hedged archive diverged from baseline");
    assert!(
        stats.hedged_won >= 1,
        "the wedged chunk must be won by a hedged duplicate: {stats:?}"
    );
    assert_eq!(stats.requeued, 0, "hedging must not masquerade as requeues");
    assert_eq!(stats.retired_shards(), 0);
    assert_balanced(&stats);

    // Open the gate: the wedged worker finishes its (already-delivered)
    // chunk, the duplicate reply is discarded by chunk id, and the service
    // drains to quiescence where the wasted copy is on the books.
    plan.release_wedges();
    drain(&svc);
    let stats = svc.stats();
    assert!(
        stats.hedged_wasted >= 1,
        "the released wedged copy must resolve as a discarded duplicate: {stats:?}"
    );
    assert_balanced(&stats);
}

#[test]
fn crashed_shard_in_process_requeues_and_converges() {
    let baseline = baseline_hash();
    // A Drop fault in an in-process flow is a shard crash: the flow retires
    // on its first chunk, the pool requeues that chunk onto the survivors.
    let spec = FaultSpec { seed: 11, kind: FaultKind::Drop, rate: 1.0 };
    let plan = Arc::new(FaultPlan::new(spec));
    let svc = faulted_local_pool(plan, HedgePolicy::disabled());
    let (hash, stats) = search_hash(&svc);
    assert_eq!(baseline, hash, "archive diverged after an in-process crash");
    assert_eq!(stats.retired_shards(), 1, "exactly the faulted shard retires");
    assert_eq!(stats.requeued, 1, "the crashed shard's chunk must requeue once");
    assert_balanced(&stats);
}

#[test]
fn delayed_server_over_loopback_converges_with_and_without_hedging() {
    let baseline = baseline_hash();
    let spec = FaultSpec { seed: 5, kind: FaultKind::Delay, rate: 1.0 };
    let plan = Arc::new(FaultPlan::new(spec).with_delay(Duration::from_millis(2)));
    let slow = spawn_test_server_with_faults(0, None, Some(plan.clone()), synth_chunk).unwrap();
    let healthy = spawn_test_server(0, None, synth_chunk).unwrap();
    for factor in [0.0, 4.0] {
        let svc = mixed_pool(
            0,
            vec![healthy.clone(), slow.clone()],
            RetryPolicy::default(),
            Duration::from_secs(30),
            HedgePolicy::from_factor(factor),
        );
        let (hash, stats) = search_hash(&svc);
        assert_eq!(
            baseline, hash,
            "slow server diverged the archive (hedge factor {factor})"
        );
        assert_eq!(stats.requeued, 0, "a slow server must never cause requeues");
        assert_eq!(stats.retired_shards(), 0);
        assert_balanced(&stats);
    }
    assert!(plan.injected() >= 1, "the seeded delay plan never fired");
}

#[test]
fn wedged_server_over_loopback_is_won_by_a_hedge_before_the_timeout() {
    let baseline = baseline_hash();
    // The server wedges one chunk on its gate (rate 1.0, one injection).
    // The hedge wins the chunk within milliseconds; the stalled feeder only
    // notices at its 250ms chunk timeout, reconnects, resends (the plan is
    // spent, so the resend evaluates cleanly), and the late duplicate is
    // discarded by chunk id — never requeued, never double-counted.
    let spec = FaultSpec { seed: 7, kind: FaultKind::Wedge, rate: 1.0 };
    let plan = Arc::new(FaultPlan::new(spec).with_max_faults(1));
    let wedged = spawn_test_server_with_faults(0, None, Some(plan.clone()), synth_chunk).unwrap();
    let healthy = spawn_test_server(0, None, synth_chunk).unwrap();
    let svc = mixed_pool(
        2,
        vec![healthy, wedged],
        fast_retry(),
        Duration::from_millis(250),
        HedgePolicy::from_factor(4.0),
    );
    let t0 = Instant::now();
    let (hash, stats) = search_hash(&svc);
    let wall = t0.elapsed();
    assert_eq!(baseline, hash, "wedged-server archive diverged from baseline");
    assert!(
        stats.hedged_won >= 1,
        "the wedged chunk must be won by a hedged duplicate: {stats:?}"
    );
    assert_eq!(stats.requeued, 0, "hedged recovery must not requeue");
    assert_balanced(&stats);
    assert!(
        wall < Duration::from_secs(60),
        "wedged-server search must converge promptly, took {wall:?}"
    );
    plan.release_wedges();
    drain(&svc);
    assert_balanced(&svc.stats());
}

#[test]
fn wedged_server_with_hedging_off_recovers_via_timeout_resend() {
    let baseline = baseline_hash();
    // Without hedging the only recovery is the chunk timeout: the feeder
    // stalls 250ms, reconnects, resends, and the capped plan lets the
    // resend through.  Slower than the hedged lane, but identical results.
    let spec = FaultSpec { seed: 7, kind: FaultKind::Wedge, rate: 1.0 };
    let plan = Arc::new(FaultPlan::new(spec).with_max_faults(1));
    let wedged = spawn_test_server_with_faults(0, None, Some(plan.clone()), synth_chunk).unwrap();
    let healthy = spawn_test_server(0, None, synth_chunk).unwrap();
    let svc = mixed_pool(
        2,
        vec![healthy, wedged],
        fast_retry(),
        Duration::from_millis(250),
        HedgePolicy::disabled(),
    );
    let (hash, stats) = search_hash(&svc);
    assert_eq!(baseline, hash, "timeout-resend archive diverged from baseline");
    assert_eq!(stats.hedged_dispatched, 0, "hedging was disabled");
    assert_balanced(&stats);
    plan.release_wedges();
    drain(&svc);
}

#[test]
fn disconnecting_server_over_mixed_topology_converges() {
    let baseline = baseline_hash();
    // The server sporadically closes connections after evaluating (seeded,
    // rate 0.2): each close costs the client a reconnect-resend cycle; if
    // the retry budget ever runs out the feeder retires and the pool
    // requeues onto the two local shards and the healthy server.  Either
    // way the archive must not move.
    let spec = FaultSpec { seed: 3, kind: FaultKind::Disconnect, rate: 0.2 };
    let plan = Arc::new(FaultPlan::new(spec));
    let flaky = spawn_test_server_with_faults(0, None, Some(plan.clone()), synth_chunk).unwrap();
    let healthy = spawn_test_server(0, None, synth_chunk).unwrap();
    let svc = mixed_pool(
        2,
        vec![healthy, flaky],
        fast_retry(),
        Duration::from_secs(30),
        HedgePolicy::from_factor(4.0),
    );
    let (hash, stats) = search_hash(&svc);
    assert_eq!(baseline, hash, "flaky-server archive diverged from baseline");
    assert!(plan.decisions() >= 1, "the flaky server saw no chunks");
    assert_balanced(&stats);
}

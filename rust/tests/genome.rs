//! Genome-compatibility tests for the method-aware refactor: the default
//! single-method genome must reproduce the pre-refactor bits-only archives
//! (numerically identical genes, identical RNG stream, identical JSON
//! serialization), for every worker count; multi-method genomes must
//! actually search the method axis.

use amq::coordinator::{
    gene_bits, gene_method, run_search, Config, PooledEvaluator, SearchParams, SearchSpace,
};
use amq::data::Manifest;
use amq::exp::cache;
use amq::quant::{MethodId, MethodRegistry};
use amq::util::Rng;

/// A 4-layer toy manifest (no artifacts needed) for space construction.
const MANIFEST_JSON: &str = r#"{
    "model": {"vocab_size": 512, "d_model": 128, "n_layers": 2,
              "n_heads": 4, "d_ff": 256, "seq_len": 128,
              "rope_theta": 10000.0, "rms_eps": 1e-5},
    "group_size": 128,
    "bit_choices": [2, 3, 4],
    "eval_batch": 16,
    "layers": [
        {"name": "blk0.q", "out_features": 128, "in_features": 128},
        {"name": "blk0.down", "out_features": 128, "in_features": 256},
        {"name": "blk1.q", "out_features": 128, "in_features": 128},
        {"name": "blk1.down", "out_features": 128, "in_features": 256}
    ],
    "fp_side_names": ["embed"],
    "executables": {},
    "files": {"weights": "weights.bin"}
}"#;

fn legacy_space(n: usize) -> SearchSpace {
    // the pre-refactor literal shape: bits-only choices, one method
    SearchSpace {
        choices: vec![vec![2, 3, 4]; n],
        params: vec![128 * 128; n],
        groups: vec![128; n],
        group_size: 128,
    }
}

/// Deterministic synthetic "true evaluation", seeded purely from the
/// payload (the pool determinism contract).  On single-method configs this
/// is a pure function of the bit-widths, exactly as pre-refactor.
fn synth_jsd(cfg: &Config) -> f32 {
    let mut seed = 0x6C62_272E_07BB_0142u64;
    for &g in cfg {
        seed = seed.wrapping_mul(0x1000_0000_01B3).wrapping_add(g as u64);
    }
    let mut rng = Rng::new(seed);
    let base: f32 = cfg
        .iter()
        .enumerate()
        .map(|(i, &g)| {
            let w = if i % 3 == 0 { 1.0 } else { 0.05 };
            let method_factor = if gene_method(g) == MethodId::Rtn { 0.5 } else { 1.0 };
            // (5 - bits)^2 keeps a nonzero floor at 4 bits, so the method
            // factor matters on the quality end of the frontier too
            w * method_factor * ((5 - gene_bits(g) as i32) as f32).powi(2)
        })
        .sum();
    base + rng.f32() * 1e-4
}

fn pooled(workers: usize) -> PooledEvaluator {
    PooledEvaluator::spawn(workers, |_shard| {
        |cfg: Config| -> amq::Result<f32> { Ok(synth_jsd(&cfg)) }
    })
}

/// FNV-1a over the archive's full content (gene values, jsd bits, avg-bits
/// bits) — the reproducibility fingerprint.
fn archive_hash(archive: &amq::coordinator::Archive) -> u64 {
    let mut h = 0xCBF2_9CE4_8422_2325u64;
    let mut mix = |v: u64| {
        h ^= v;
        h = h.wrapping_mul(0x1000_0000_01B3);
    };
    for s in &archive.samples {
        for &g in &s.config {
            mix(g as u64);
        }
        mix(s.jsd.to_bits() as u64);
        mix(s.avg_bits.to_bits());
    }
    h
}

#[test]
fn single_method_archive_identical_across_paths_and_worker_counts() {
    let space = legacy_space(12);
    let mut params = SearchParams::smoke();
    params.seed = 17;

    // sequential (trait-default batching), pooled x1, pooled x4
    struct Seq(usize);
    impl amq::coordinator::ConfigEvaluator for Seq {
        fn eval_jsd(&mut self, config: &Config) -> amq::Result<f32> {
            self.0 += 1;
            Ok(synth_jsd(config))
        }
        fn count(&self) -> usize {
            self.0
        }
    }
    let a = run_search(&space, &mut Seq(0), &params).unwrap();
    let b = run_search(&space, &mut pooled(1), &params).unwrap();
    let c = run_search(&space, &mut pooled(4), &params).unwrap();

    let ha = archive_hash(&a.archive);
    assert_eq!(ha, archive_hash(&b.archive), "pooled x1 diverged from sequential");
    assert_eq!(ha, archive_hash(&c.archive), "pooled x4 diverged from sequential");

    // every gene of the default genome is numerically a bare bit-width —
    // the pre-refactor archive value domain
    for s in &a.archive.samples {
        for &g in &s.config {
            assert!(g <= 0xFF, "single-method gene {g:#06x} left the bits-only domain");
            assert_eq!(gene_method(g), MethodId::Hqq);
        }
    }
}

#[test]
fn single_method_space_constructors_agree() {
    // with_methods(hqq) must build the very space the legacy literal built:
    // same choices, same RNG stream, same search result
    let m = Manifest::from_json(MANIFEST_JSON).unwrap();
    let reg = MethodRegistry::default();
    let space = SearchSpace::with_methods(&m, &reg);
    let full = SearchSpace::full(&m); // manifest defaults to ["hqq"]
    assert_eq!(space.choices, full.choices);
    assert_eq!(space.choices[0], vec![2u16, 3, 4]);

    let mut params = SearchParams::smoke();
    params.seed = 23;
    let a = run_search(&space, &mut pooled(2), &params).unwrap();
    let b = run_search(&full, &mut pooled(3), &params).unwrap();
    assert_eq!(archive_hash(&a.archive), archive_hash(&b.archive));
}

#[test]
fn legacy_archive_json_byte_format_unchanged() {
    // the serialized archive of a single-method run is byte-identical to
    // the pre-refactor format: configs are bare integers
    let mut a = amq::coordinator::Archive::new();
    a.insert(vec![2, 3], 0.125, 2.75);
    a.insert(vec![4, 4], 0.5, 4.25);
    let dir = std::env::temp_dir().join("amq_genome_test");
    let path = dir.join("legacy.json");
    cache::save_archive(&path, &a).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    assert_eq!(
        text,
        "{\"samples\": [\
         {\"config\": [2,3], \"jsd\": 0.125, \"bits\": 2.75},\
         {\"config\": [4,4], \"jsd\": 0.5, \"bits\": 4.25}]}"
    );
    let back = cache::load_archive(&path).unwrap();
    assert_eq!(archive_hash(&a), archive_hash(&back));
}

#[test]
fn multi_method_search_opens_the_method_axis() {
    // two methods -> genome doubles per layer; the synthetic evaluator
    // halves the penalty of rtn genes, so the search must discover them
    let m = Manifest::from_json(MANIFEST_JSON).unwrap();
    let reg = MethodRegistry::parse("hqq,rtn").unwrap();
    let space = SearchSpace::with_methods(&m, &reg);
    let single = SearchSpace::with_methods(&m, &MethodRegistry::default());
    let n = m.layers.len() as f64;
    assert!((single.log10_size() - n * 3f64.log10()).abs() < 1e-9);
    assert!(
        (space.log10_size() - n * 6f64.log10()).abs() < 1e-9,
        "two methods x three bit-widths must give 6 gene choices per layer: {}",
        space.log10_size()
    );

    let mut params = SearchParams::smoke();
    params.seed = 41;
    let res = run_search(&space, &mut pooled(3), &params).unwrap();
    assert!(!res.archive.is_empty());
    let mut rtn_genes = 0usize;
    let mut total = 0usize;
    for s in &res.archive.samples {
        assert!(space.contains(&s.config));
        total += s.config.len();
        rtn_genes += s
            .config
            .iter()
            .filter(|&&g| gene_method(g) == MethodId::Rtn)
            .count();
    }
    assert!(rtn_genes > 0, "search never explored the second method");
    assert!(rtn_genes < total, "search collapsed onto one method");
    // the favored method must beat anything the hqq-only genome can say:
    // the best hqq-only jsd is the all-hqq@4 floor, so going below it
    // requires rtn genes on the quality end of the frontier
    let best = res
        .archive
        .samples
        .iter()
        .min_by(|a, b| a.jsd.partial_cmp(&b.jsd).unwrap())
        .unwrap();
    let hqq_floor = synth_jsd(&single.uniform(4));
    assert!(
        best.jsd < hqq_floor - 1e-3,
        "best jsd {} should beat the hqq-only floor {hqq_floor}",
        best.jsd
    );
    let best_rtn = best
        .config
        .iter()
        .filter(|&&g| gene_method(g) == MethodId::Rtn)
        .count();
    assert!(best_rtn > 0, "a floor-beating config must carry rtn genes");
    // determinism across worker counts holds for the widened genome too
    let res2 = run_search(&space, &mut pooled(1), &params).unwrap();
    assert_eq!(archive_hash(&res.archive), archive_hash(&res2.archive));
}

//! Integration tests over the real artifacts + PJRT runtime.
//!
//! These need `make artifacts` to have run; they skip (with a notice) when
//! artifacts are absent so a clean checkout still passes `cargo test`.
//! PJRT client + executable compilation is expensive on this single-core
//! testbed, so the runtime-level assertions share one `#[test]` body.

use amq::coordinator::{gene, ProxyBank, SearchSpace};
use amq::data::{load_tokens, Manifest};
use amq::eval::{self, ModelHandle};
use amq::model::ModelAssets;
use amq::quant::{Hqq, MethodId, MethodRegistry, Quantizer, Rtn};
use amq::runtime::{
    pack_lane_slab, planned_scorer_variant, planned_slab_gather, Runtime, ScorerVariant,
    SlabGatherMode,
};

macro_rules! require_artifacts {
    () => {
        if !amq::artifacts_available() {
            eprintln!("[skip] artifacts/ missing — run `make artifacts`");
            return;
        }
    };
}

#[test]
fn assets_load_and_validate() {
    require_artifacts!();
    let dir = amq::artifacts_dir();
    let assets = ModelAssets::load(&dir).unwrap();
    assert_eq!(assets.manifest.layers.len(), assets.manifest.model.n_layers * 7);
    assert_eq!(assets.manifest.group_size, 128);
    // calibration splits exist with the right geometry
    let calib = load_tokens(&assets.manifest.file("calib").unwrap()).unwrap();
    assert_eq!(calib.seq_len, assets.manifest.model.seq_len);
    assert!(calib.n_seqs >= assets.manifest.eval_batch);
    let tasks = amq::data::load_tasks(&assets.manifest.file("tasks").unwrap()).unwrap();
    assert!(!tasks.is_empty());
}

#[test]
fn proxy_bank_builds_from_artifacts() {
    // Host-side only (no PJRT client needed): the multi-method bank builds
    // from the real weights, every (method, layer, bits) piece is
    // addressable, and the per-method accounting agrees with the space.
    require_artifacts!();
    let dir = amq::artifacts_dir();
    let assets = ModelAssets::load(&dir).unwrap();
    let registry = MethodRegistry::parse("hqq,rtn").unwrap();
    let bank = ProxyBank::build(
        &assets.manifest,
        &assets.weights,
        Some(&assets.hessians),
        &registry,
    )
    .unwrap();
    assert_eq!(bank.n_layers(), assets.manifest.layers.len());
    assert_eq!(bank.stats.len(), 2);
    let space = SearchSpace::with_methods(&assets.manifest, &registry);
    for m in [MethodId::Hqq, MethodId::Rtn] {
        for &b in &assets.manifest.bit_choices {
            let cfg = vec![gene(m, b); assets.manifest.layers.len()];
            let bank_bytes: usize = (0..assets.manifest.layers.len())
                .map(|li| bank.piece(li, cfg[li]).unwrap().memory_bytes())
                .sum();
            let space_bytes = space.memory_mb(&cfg) * 1e6;
            assert!(
                (space_bytes - bank_bytes as f64).abs() < 1e-6 * space_bytes,
                "{m:?}@{b}: space {space_bytes} vs bank {bank_bytes}"
            );
        }
    }
    // single-method bank pieces are identical to the multi-method bank's
    // hqq slot (shared loads must not change quantization)
    let single = ProxyBank::build(
        &assets.manifest,
        &assets.weights,
        None,
        &MethodRegistry::default(),
    )
    .unwrap();
    let li = assets.manifest.layers.len() / 2;
    assert_eq!(
        single.piece(li, gene(MethodId::Hqq, 3)).unwrap().codes,
        bank.piece(li, gene(MethodId::Hqq, 3)).unwrap().codes
    );
}

#[test]
fn lane_scorer_artifact_wired_through_manifest() {
    // Host-side only: the AOT build ships a lane-stacked scorer whose
    // manifest entry the runtime's lane planner resolves, and whose HLO
    // file actually exists with the same flat argument names as the
    // single-candidate scorer (the arg planner reuses one classification).
    require_artifacts!();
    let dir = amq::artifacts_dir();
    let m = Manifest::load(&dir).unwrap();
    let Some(lanes) = m.scorer_lanes() else {
        eprintln!("[skip] artifacts built without a lane-stacked scorer (AMQ_SCORE_LANES=1)");
        return;
    };
    assert!(lanes > 1);
    let exe = m.executable("scores_quant_lanes").unwrap();
    assert_eq!(exe.lanes, Some(lanes));
    assert!(m.hlo_path("scores_quant_lanes").unwrap().exists());
    assert_eq!(exe.args, m.executable("scores_quant").unwrap().args);
    // lane planning: auto follows the artifact, --lanes 1 opts out,
    // a mismatched explicit request is an error
    assert_eq!(
        planned_scorer_variant(&m, 0).unwrap(),
        ScorerVariant::LaneStacked { lanes }
    );
    assert_eq!(
        planned_scorer_variant(&m, 1).unwrap(),
        ScorerVariant::PerCandidate
    );
    assert!(planned_scorer_variant(&m, lanes + 1).is_err());
}

#[test]
fn gather_artifact_wired_through_manifest() {
    // Host-side only: the AOT build ships one gather executable per quant
    // shape family, each stacking the same lane count as the scorer, and
    // the runtime's gather planner routes slab-cache misses through them.
    require_artifacts!();
    let dir = amq::artifacts_dir();
    let m = Manifest::load(&dir).unwrap();
    let Some(lanes) = m.scorer_lanes() else {
        eprintln!("[skip] artifacts built without a lane-stacked scorer (AMQ_SCORE_LANES=1)");
        return;
    };
    let Some(gather_lanes) = m.gather_lanes() else {
        eprintln!("[skip] artifacts built without gather executables (AMQ_SLAB_GATHER=0)");
        return;
    };
    assert_eq!(gather_lanes, lanes, "gather lanes must match the scorer");
    let families = m.shape_families();
    assert!(!families.is_empty());
    for &(n, k) in &families {
        let key = Manifest::gather_key(n, k);
        let exe = m.executable(&key).unwrap();
        assert_eq!(exe.lanes, Some(lanes));
        assert_eq!(exe.outputs, ["codes", "scale", "zero"]);
        assert_eq!(exe.args.len(), 3 * lanes, "lane-major (codes, scale, zero) triples");
        assert!(m.hlo_path(&key).unwrap().exists());
    }
    // gather planning: auto and require route misses through the device
    // gather, off and the per-candidate scorer (--lanes 1) keep host packing
    assert!(planned_slab_gather(&m, 0, SlabGatherMode::Auto).unwrap());
    assert!(planned_slab_gather(&m, lanes, SlabGatherMode::Require).unwrap());
    assert!(!planned_slab_gather(&m, 0, SlabGatherMode::Off).unwrap());
    assert!(!planned_slab_gather(&m, 1, SlabGatherMode::Auto).unwrap());
}

#[test]
fn runtime_end_to_end() {
    require_artifacts!();
    let dir = amq::artifacts_dir();
    let assets = ModelAssets::load(&dir).unwrap();
    let m: &Manifest = &assets.manifest;
    // The vendored `xla` stub has no real PJRT backend; skip (don't fail)
    // when no client can be created so artifact-bearing CI still runs the
    // host-side integration tests above.
    let rt = match Runtime::load(&dir, &assets.weights) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("[skip] no PJRT backend available: {e}");
            return;
        }
    };
    let b = rt.batch_size();
    let t = rt.seq_len();
    let v = rt.vocab();

    // -- golden: rust-side fp logits match the python-side reference -----
    let golden = amq::data::Bundle::read(&m.file("golden").unwrap()).unwrap();
    let gtoks = golden.tensor("tokens").unwrap();
    let gfp = golden.tensor("fp_logits").unwrap();
    assert_eq!(gtoks.shape, vec![b, t]);
    let logits = rt.fp_logits(gtoks.as_i32().unwrap()).unwrap();
    assert_eq!(logits.len(), b * t * v);
    let want = gfp.as_f32().unwrap(); // first 2 sequences only
    let mut max_err = 0.0f32;
    for (i, &w) in want.iter().enumerate() {
        max_err = max_err.max((logits[i] - w).abs());
    }
    assert!(
        max_err < 5e-2,
        "fp logits deviate from python golden: max abs err {max_err}"
    );

    // -- scorer consistency: fused (jsd, ce) vs rust-mirror computation --
    let calib = load_tokens(&m.file("calib").unwrap()).unwrap();
    let toks = calib.batch(0, b);
    let mask = vec![1.0f32; b * t];
    let batch = rt.prepare_batch(toks, &mask).unwrap();

    // quantize every layer at 3 bits with HQQ (the proxy quantizer); keep
    // the host pieces — they are the borrowed pack source of the lane path
    let hqq = Hqq::default();
    let mut p3 = Vec::new();
    let mut qlayers = Vec::new();
    for l in &m.layers {
        let w = assets.weights.linear(&l.name).unwrap();
        let q = hqq.quantize(&w, 3, m.group_size, None);
        qlayers.push(rt.upload_quant_layer(&q).unwrap());
        p3.push(q);
    }
    let refs: Vec<&_> = qlayers.iter().collect();
    let (jsd_fused, ce_fused) = rt.scores(&batch, &refs).unwrap();
    assert!(jsd_fused.is_finite() && jsd_fused > 0.0);
    assert!(ce_fused > 0.0 && ce_fused < 10.0);

    // mirror: quant logits -> rust jsd/ce
    let qlogits = rt.quant_logits(toks, &refs).unwrap();
    let jsd_mirror = eval::jsd_mean(&batch.host_fp_logits, &qlogits, v, &mask);
    let ce_mirror = eval::cross_entropy(&qlogits, toks, &mask, b, t, v);
    assert!(
        (jsd_fused - jsd_mirror).abs() < 2e-3,
        "fused jsd {jsd_fused} vs mirror {jsd_mirror}"
    );
    assert!(
        (ce_fused - ce_mirror).abs() < 2e-2,
        "fused ce {ce_fused} vs mirror {ce_mirror}"
    );

    // -- monotonicity: 2-bit hurts more than 4-bit --------------------------
    let mut p2 = Vec::new();
    let mut p4 = Vec::new();
    let mut q2 = Vec::new();
    let mut q4 = Vec::new();
    for l in &m.layers {
        let w = assets.weights.linear(&l.name).unwrap();
        let a = hqq.quantize(&w, 2, m.group_size, None);
        let b = hqq.quantize(&w, 4, m.group_size, None);
        q2.push(rt.upload_quant_layer(&a).unwrap());
        q4.push(rt.upload_quant_layer(&b).unwrap());
        p2.push(a);
        p4.push(b);
    }
    let r2: Vec<&_> = q2.iter().collect();
    let r4: Vec<&_> = q4.iter().collect();
    let (jsd2, _) = rt.scores(&batch, &r2).unwrap();
    let (jsd4, _) = rt.scores(&batch, &r4).unwrap();

    // -- lane-stacked dispatch is invisible in the results ----------------
    // A multi-candidate chunk dispatches through a LaneChunkPlan whose
    // slabs are packed from rows borrowed straight from the host pieces
    // and held in a SlabCache; per-candidate `scores` calls above are the
    // reference.  The contract is *bitwise* equality per candidate.
    if let ScorerVariant::LaneStacked { lanes } = rt.scorer_variant() {
        use amq::coordinator::slab_budget_bytes;
        use amq::runtime::{lane_slab_sig, LaneChunkPlan, LaneGroup, LaneSlabCache};
        assert!(lanes >= 3, "default artifact lane count should hold a 3-chunk");
        let n_layers = m.layers.len();
        let cache = LaneSlabCache::new(slab_budget_bytes(64));
        let group: Vec<Vec<u16>> = [2u16, 3, 4]
            .iter()
            .map(|&b| vec![b; n_layers])
            .collect();
        let resolve = |cache: &LaneSlabCache| -> LaneChunkPlan {
            let mut slabs = Vec::with_capacity(n_layers);
            for li in 0..n_layers {
                let sig = lane_slab_sig(&group, li, lanes);
                let slab = cache
                    .get_or_build((li, sig), || {
                        let pieces = [&p2[li], &p3[li], &p4[li]];
                        let bufs = rt.upload_lane_slab(&pieces)?;
                        let bytes = bufs.bytes;
                        Ok((bufs, bytes))
                    })
                    .unwrap();
                slabs.push(slab);
            }
            LaneChunkPlan::new(vec![LaneGroup { real: 3, slabs }]).unwrap()
        };
        let plan = resolve(&cache);
        assert_eq!(cache.stats().misses, n_layers as u64);
        let before = rt.stats();
        let chunk = rt.scores_lane_chunk(&batch, &plan).unwrap();
        assert_eq!(chunk[0].0.to_bits(), jsd2.to_bits(), "lane 0 jsd drifted");
        assert_eq!(chunk[1].0.to_bits(), jsd_fused.to_bits(), "lane 1 jsd drifted");
        assert_eq!(chunk[2].0.to_bits(), jsd4.to_bits(), "lane 2 jsd drifted");
        assert_eq!(chunk[1].1.to_bits(), ce_fused.to_bits(), "lane 1 ce drifted");
        // replaying the pinned plan (the multi-calibration-batch shape)
        // costs zero further uploads and reproduces the results bitwise
        let upload_mark = rt.stats().upload_bytes;
        let chunk2 = rt.scores_lane_chunk(&batch, &plan).unwrap();
        assert_eq!(rt.stats().upload_bytes, upload_mark, "plan replay uploaded");
        for (a, b) in chunk.iter().zip(&chunk2) {
            assert_eq!(a.0.to_bits(), b.0.to_bits());
            assert_eq!(a.1.to_bits(), b.1.to_bits());
        }
        // re-resolving the same candidate group is pure cache hits
        let _plan2 = resolve(&cache);
        let cs = cache.stats();
        assert_eq!(cs.misses, n_layers as u64, "re-resolve must not re-pack");
        assert_eq!(cs.hits, n_layers as u64);
        assert!(cs.resident_bytes > 0);
        let after = rt.stats();
        // 3 candidates <= L lanes: one lane dispatch per replay, padded tail
        assert_eq!(after.lane_dispatches - before.lane_dispatches, 2);
        assert_eq!(after.lane_candidates - before.lane_candidates, 6);
        assert_eq!(
            after.lane_padded - before.lane_padded,
            2 * (lanes - 3) as u64
        );
        assert_eq!(after.scores_calls, before.scores_calls, "no per-candidate calls");

        // -- device-side gather is bitwise the host packer ---------------
        // A *partial* group (2 real lanes of L) gathered on device from
        // the resident quant buffers must read back exactly the bytes
        // pack_lane_slab builds on the host — including the repeated
        // lane-0 padding region — with zero host→device upload traffic.
        if rt.slab_gather_enabled() {
            let host = [&p2[0], &p4[0]];
            let code_rows: Vec<&[u8]> = host.iter().map(|p| p.codes.as_slice()).collect();
            let want_codes: Vec<i8> = pack_lane_slab(&code_rows, lanes)
                .unwrap()
                .iter()
                .map(|&c| c as i8)
                .collect();
            let scale_rows: Vec<&[f32]> = host.iter().map(|p| p.scale.as_slice()).collect();
            let want_scale = pack_lane_slab(&scale_rows, lanes).unwrap();
            let zero_rows: Vec<&[f32]> = host.iter().map(|p| p.zero.as_slice()).collect();
            let want_zero = pack_lane_slab(&zero_rows, lanes).unwrap();

            let mark = rt.stats();
            let slab = rt.gather_lane_slab(&[&q2[0], &q4[0]]).unwrap();
            let gstats = rt.stats();
            assert_eq!(
                gstats.upload_bytes, mark.upload_bytes,
                "device gather must not touch the host upload path"
            );
            assert_eq!(gstats.gather_dispatches, mark.gather_dispatches + 1);
            assert_eq!(
                gstats.slab_upload_bytes_avoided - mark.slab_upload_bytes_avoided,
                slab.bytes as u64,
                "bytes avoided must be what upload_lane_slab would have pushed"
            );
            // the host route reports identical slab bytes for this group
            let uploaded = rt.upload_lane_slab(&[&p2[0], &p4[0]]).unwrap();
            assert_eq!(slab.bytes, uploaded.bytes);

            let got_codes =
                slab.codes.to_literal_sync().unwrap().to_vec::<i8>().unwrap();
            assert_eq!(got_codes, want_codes, "gathered codes drifted from host pack");
            let got_scale =
                slab.scale.to_literal_sync().unwrap().to_vec::<f32>().unwrap();
            assert_eq!(got_scale.len(), want_scale.len());
            for (i, (a, b)) in got_scale.iter().zip(&want_scale).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "gathered scale[{i}] drifted");
            }
            let got_zero =
                slab.zero.to_literal_sync().unwrap().to_vec::<f32>().unwrap();
            assert_eq!(got_zero.len(), want_zero.len());
            for (i, (a, b)) in got_zero.iter().zip(&want_zero).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "gathered zero[{i}] drifted");
            }
        } else {
            eprintln!("[skip] gather executables absent — host-pack route only");
        }
    }
    assert!(
        jsd2 > jsd_fused && jsd_fused > jsd4,
        "JSD should be monotone in bits: 2b={jsd2} 3b={jsd_fused} 4b={jsd4}"
    );
    assert!(jsd4 < 0.05, "4-bit HQQ should be near-lossless, jsd={jsd4}");

    // -- fp PPL sane on the test split -----------------------------------
    let wiki = load_tokens(&m.file("test_wiki").unwrap()).unwrap();
    let ppl_fp = eval::perplexity_on(&rt, &ModelHandle::Fp, &wiki).unwrap();
    assert!(
        ppl_fp > 1.0 && ppl_fp < 40.0,
        "trained-model wiki PPL should be modest, got {ppl_fp}"
    );
    // 4-bit quant ppl close to fp; 2-bit worse
    let ppl_q4 = eval::perplexity_on(&rt, &ModelHandle::Quant(&r4), &wiki).unwrap();
    let ppl_q2 = eval::perplexity_on(&rt, &ModelHandle::Quant(&r2), &wiki).unwrap();
    assert!(ppl_q4 < ppl_q2, "4-bit PPL {ppl_q4} !< 2-bit PPL {ppl_q2}");
    assert!(ppl_q4 < ppl_fp * 1.3, "4-bit PPL {ppl_q4} vs fp {ppl_fp}");

    // -- override path: RTN-dequantized weights through the fp graph -----
    let rtn = Rtn;
    let mut overrides = std::collections::HashMap::new();
    for l in &m.layers {
        let w = assets.weights.linear(&l.name).unwrap();
        let dq = rtn.quantize(&w, 4, m.group_size, None).dequant();
        overrides.insert(
            l.name.clone(),
            rt.upload_f32(&dq.data, &[dq.rows, dq.cols]).unwrap(),
        );
    }
    let ppl_ov =
        eval::perplexity_on(&rt, &ModelHandle::Override(&overrides), &wiki).unwrap();
    assert!(ppl_ov < ppl_fp * 1.3, "override PPL {ppl_ov} vs fp {ppl_fp}");

    // -- task scoring runs and fp is above chance ---------------------------
    let tasks = amq::data::load_tasks(&m.file("tasks").unwrap()).unwrap();
    let subset: Vec<_> = tasks
        .iter()
        .filter(|t| t.family == "recall" || t.family == "agreement")
        .take(60)
        .cloned()
        .collect();
    let res = eval::tasks_on(&rt, &ModelHandle::Fp, &subset, m.pad_token()).unwrap();
    let avg = res.macro_avg(&["recall", "agreement"]);
    assert!(avg > 40.0, "fp model should beat 25% chance clearly, got {avg}");
}

//! Sharded-evaluation-pool integration tests (no artifacts required):
//! the pool must speed up queue-bound workloads without changing a single
//! bit of the search result — `--workers 1` and `--workers 4` archives are
//! identical for a fixed seed.

use amq::coordinator::synth::{synth_jsd, synth_space};
use amq::coordinator::{run_search, Config, ConfigEvaluator, PooledEvaluator, SearchParams, SearchSpace};
use amq::runtime::EvalService;
use std::time::{Duration, Instant};

/// The shared deterministic workload (`coordinator::synth`) — the same
/// functions the remote-shard suite and the CI `pool-smoke` command score,
/// so this file pins the in-process half of the topology contract.
fn toy_space(n: usize) -> SearchSpace {
    synth_space(n)
}

fn pooled(workers: usize) -> PooledEvaluator {
    PooledEvaluator::spawn(workers, |_shard| {
        |cfg: Config| -> amq::Result<f32> { Ok(synth_jsd(&cfg)) }
    })
}

#[test]
fn search_archive_identical_across_worker_counts() {
    let space = toy_space(12);
    let mut params = SearchParams::smoke();
    params.seed = 17;

    let mut ev1 = pooled(1);
    let a = run_search(&space, &mut ev1, &params).unwrap();
    let mut ev4 = pooled(4);
    let b = run_search(&space, &mut ev4, &params).unwrap();

    assert_eq!(a.archive.len(), b.archive.len());
    for (x, y) in a.archive.samples.iter().zip(&b.archive.samples) {
        assert_eq!(x.config, y.config, "configs diverge across worker counts");
        assert_eq!(x.jsd.to_bits(), y.jsd.to_bits(), "jsd not bit-identical");
        assert_eq!(x.avg_bits.to_bits(), y.avg_bits.to_bits());
    }
    assert_eq!(a.true_evals, b.true_evals);
    assert_eq!(a.predictor_queries, b.predictor_queries);
}

#[test]
fn pooled_matches_sequential_trait_default() {
    // The pool must agree with the plain sequential ConfigEvaluator path.
    struct Seq {
        evals: usize,
    }
    impl ConfigEvaluator for Seq {
        fn eval_jsd(&mut self, config: &Config) -> amq::Result<f32> {
            self.evals += 1;
            Ok(synth_jsd(config))
        }
        fn count(&self) -> usize {
            self.evals
        }
    }

    let space = toy_space(10);
    let mut params = SearchParams::smoke();
    params.seed = 5;
    let a = run_search(&space, &mut Seq { evals: 0 }, &params).unwrap();
    let mut ev = pooled(3);
    let b = run_search(&space, &mut ev, &params).unwrap();
    assert_eq!(a.archive.len(), b.archive.len());
    for (x, y) in a.archive.samples.iter().zip(&b.archive.samples) {
        assert_eq!(x.config, y.config);
        assert_eq!(x.jsd.to_bits(), y.jsd.to_bits());
    }
}

#[test]
fn pool_throughput_scales_on_queue_bound_workload() {
    // Each "evaluation" blocks for 10ms (a stand-in for a device round
    // trip).  Four shards must clear a 32-candidate batch well under the
    // sequential time — generous margins to stay robust on loaded CI boxes.
    const DELAY: Duration = Duration::from_millis(10);
    const BATCH: u32 = 32;

    let run = |workers: usize| {
        let svc: EvalService<u32, u32> = EvalService::spawn_sharded(workers, |_shard| {
            |x: u32| {
                std::thread::sleep(DELAY);
                x
            }
        });
        let t0 = Instant::now();
        let out = svc.call_batch((0..BATCH).collect()).unwrap();
        let elapsed = t0.elapsed();
        assert_eq!(out, (0..BATCH).collect::<Vec<_>>());
        elapsed
    };

    let sequential_floor = DELAY * BATCH; // 320ms of pure work
    let t1 = run(1);
    assert!(
        t1 >= sequential_floor,
        "1 worker finished {t1:?}, below the physical floor {sequential_floor:?}"
    );
    let t4 = run(4);
    // 4 shards: ideal 80ms; require merely < 75% of the 1-worker floor.
    assert!(
        t4 < sequential_floor * 3 / 4,
        "4 workers took {t4:?}, expected well under {sequential_floor:?}"
    );
}

#[test]
fn pool_reports_per_shard_stats() {
    let svc: EvalService<u32, u32> = EvalService::spawn_sharded(4, |_shard| {
        |x: u32| {
            std::thread::sleep(Duration::from_millis(3));
            x * 2
        }
    });
    let _ = svc.call_batch((0..20).collect()).unwrap();
    let stats = svc.stats();
    assert_eq!(stats.completed, 20);
    assert_eq!(stats.per_shard.len(), 4);
    assert_eq!(stats.per_shard.iter().map(|s| s.completed).sum::<u64>(), 20);
    let active = stats.per_shard.iter().filter(|s| s.completed > 0).count();
    assert!(active >= 2, "work should spread across shards, got {active}");
    assert!(stats.total_service_time >= Duration::from_millis(20 * 3));
}

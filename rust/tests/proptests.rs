//! Property-based tests on coordinator + quantizer invariants.
//!
//! The offline build has no `proptest`, so these are hand-rolled randomized
//! properties: many seeded trials per invariant, with the failing seed
//! printed so a failure is reproducible.

use amq::coordinator::archive::pareto_front_of;
use amq::coordinator::nsga2::{self, dominates, Individual};
use amq::coordinator::space::SearchSpace;
use amq::coordinator::synth::synth_chunk;
use amq::coordinator::{gene, gene_bits, Archive, Config, EvalPool, Gene, ProxyBank};
use amq::quant::{frob_error, pack, Hqq, MethodId, Quantizer, Rtn};
use amq::runtime::{
    lane_routed, lane_slab_sig, pack_lane_slab, EvalService, FaultKind, FaultPlan, FaultSpec,
    HedgePolicy, ShardFlow, SlabCache,
};
use amq::tensor::Mat;
use amq::util::Rng;
use std::sync::Arc;
use std::time::{Duration, Instant};

const TRIALS: usize = 60;

fn rand_space(rng: &mut Rng) -> SearchSpace {
    let n = rng.range(2, 32);
    let mut choices = Vec::new();
    for _ in 0..n {
        let set: Vec<Gene> = match rng.below(4) {
            0 => vec![2, 3, 4],
            1 => vec![2, 4],
            2 => vec![3, 4],
            _ => vec![4],
        };
        choices.push(set);
    }
    SearchSpace {
        params: (0..n).map(|_| 128 * (1 + rng.below(4))).collect(),
        groups: (0..n).map(|_| 1 + rng.below(4)).collect(),
        choices,
        group_size: 128,
    }
}

/// A random *multi-method* space: every layer offers the cross product of a
/// random subset of methods and a random bit set.
fn rand_method_space(rng: &mut Rng) -> SearchSpace {
    let n = rng.range(2, 24);
    let methods: &[MethodId] = match rng.below(3) {
        0 => &[MethodId::Hqq, MethodId::Rtn],
        1 => &[MethodId::Hqq, MethodId::Rtn, MethodId::Gptq],
        _ => &[MethodId::Rtn, MethodId::AwqClip],
    };
    let mut choices = Vec::new();
    for _ in 0..n {
        let bits: &[u8] = match rng.below(3) {
            0 => &[2, 3, 4],
            1 => &[2, 4],
            _ => &[3, 4],
        };
        let set: Vec<Gene> = methods
            .iter()
            .flat_map(|&m| bits.iter().map(move |&b| gene(m, b)))
            .collect();
        choices.push(set);
    }
    SearchSpace {
        params: (0..n).map(|_| 128 * (1 + rng.below(4))).collect(),
        groups: (0..n).map(|_| 1 + rng.below(4)).collect(),
        choices,
        group_size: 128,
    }
}

fn rand_mat(rng: &mut Rng, n: usize, k: usize) -> Mat {
    let mut w = Mat::zeros(n, k);
    for v in &mut w.data {
        *v = rng.normal() * 0.15;
    }
    w
}

// ---------------------------------------------------------------------------
// Space invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_random_configs_are_contained_and_bounded() {
    for seed in 0..TRIALS as u64 {
        let mut rng = Rng::new(seed);
        let space = rand_space(&mut rng);
        let cfg = space.random(&mut rng);
        assert!(space.contains(&cfg), "seed {seed}");
        let bits = space.avg_bits(&cfg);
        assert!((2.0..=4.5).contains(&bits), "seed {seed}: {bits}");
    }
}

#[test]
fn prop_repair_is_idempotent_and_contained() {
    for seed in 0..TRIALS as u64 {
        let mut rng = Rng::new(1000 + seed);
        let space = rand_space(&mut rng);
        let mut cfg: Config = (0..space.n_layers())
            .map(|_| [1u16, 2, 3, 4, 5][rng.below(5)])
            .collect();
        space.repair(&mut cfg);
        assert!(space.contains(&cfg), "seed {seed}");
        let again = {
            let mut c = cfg.clone();
            space.repair(&mut c);
            c
        };
        assert_eq!(cfg, again, "seed {seed}: repair not idempotent");
    }
}

#[test]
fn prop_avg_bits_monotone_in_any_single_gene() {
    for seed in 0..TRIALS as u64 {
        let mut rng = Rng::new(2000 + seed);
        let space = rand_space(&mut rng);
        let cfg = space.random(&mut rng);
        let li = rng.below(space.n_layers());
        for &b in &space.choices[li] {
            for &b2 in &space.choices[li] {
                if b2 <= b {
                    continue;
                }
                let mut lo = cfg.clone();
                lo[li] = b;
                let mut hi = cfg.clone();
                hi[li] = b2;
                assert!(
                    space.avg_bits(&lo) < space.avg_bits(&hi),
                    "seed {seed}"
                );
            }
        }
    }
}

#[test]
fn prop_multi_method_space_ops_contained() {
    // the single-method invariants must survive the method axis: random and
    // repaired configs stay in the space, min/max/uniform/demote respect it,
    // and avg_bits is monotone in any single gene's bits
    for seed in 0..TRIALS as u64 {
        let mut rng = Rng::new(12_000 + seed);
        let space = rand_method_space(&mut rng);
        let cfg = space.random(&mut rng);
        assert!(space.contains(&cfg), "seed {seed}");
        assert!(space.contains(&space.min_config()), "seed {seed}");
        assert!(space.contains(&space.max_config()), "seed {seed}");
        assert!(
            space.avg_bits(&space.min_config()) <= space.avg_bits(&cfg)
                && space.avg_bits(&cfg) <= space.avg_bits(&space.max_config()),
            "seed {seed}"
        );
        let mut mangled: Config = cfg.clone();
        let li = rng.below(space.n_layers());
        mangled[li] = gene(MethodId::AwqClip, 7);
        space.repair(&mut mangled);
        assert!(space.contains(&mangled), "seed {seed}: repair left the space");
        if let Some(g) = space.demote(li, cfg[li]) {
            assert!(space.choices[li].contains(&g), "seed {seed}");
            assert!(gene_bits(g) < gene_bits(cfg[li]), "seed {seed}");
        }
        // feature dimension: bits block + one-hot block for active layers
        let active = space.active_layers();
        let f = space.features(&cfg, &active);
        let expect = if space.n_methods() > 1 {
            active.len() * (1 + space.n_methods())
        } else {
            active.len()
        };
        assert_eq!(f.len(), expect, "seed {seed}");
    }
}

#[test]
fn prop_space_accounting_matches_proxy_bank() {
    // SearchSpace::avg_bits / memory_mb must agree with the bank's
    // per-piece memory_bytes() for every enabled (method, bits) pair
    for seed in 0..8u64 {
        let mut rng = Rng::new(13_000 + seed);
        let methods = [MethodId::Hqq, MethodId::Rtn];
        let gs = 128usize;
        let bit_choices = [2u8, 3, 4];
        // random layer geometry (rows x groups-of-128 columns)
        let n_layers = rng.range(1, 4);
        let geom: Vec<(usize, usize)> = (0..n_layers)
            .map(|_| (8 * rng.range(1, 3), gs * rng.range(1, 3)))
            .collect();
        let mats: Vec<Mat> = geom.iter().map(|&(n, k)| rand_mat(&mut rng, n, k)).collect();
        let pieces: Vec<Vec<Vec<_>>> = methods
            .iter()
            .map(|m| {
                let q = m.build();
                mats.iter()
                    .map(|w| bit_choices.iter().map(|&b| q.quantize(w, b, gs, None)).collect())
                    .collect()
            })
            .collect();
        let bank = ProxyBank::from_parts(methods.to_vec(), bit_choices.to_vec(), pieces).unwrap();
        let space = SearchSpace {
            choices: vec![
                methods
                    .iter()
                    .flat_map(|&m| bit_choices.iter().map(move |&b| gene(m, b)))
                    .collect();
                n_layers
            ],
            params: geom.iter().map(|&(n, k)| n * k).collect(),
            groups: geom.iter().map(|&(n, k)| n * k / gs).collect(),
            group_size: gs,
        };
        let total_params: usize = space.params.iter().sum();
        for &m in &methods {
            for &b in &bit_choices {
                let cfg: Config = vec![gene(m, b); n_layers];
                let bank_bytes: usize =
                    (0..n_layers).map(|li| bank.piece(li, cfg[li]).unwrap().memory_bytes()).sum();
                let space_bytes = space.memory_mb(&cfg) * 1e6;
                assert!(
                    (space_bytes - bank_bytes as f64).abs() < 1e-6 * space_bytes.max(1.0),
                    "seed {seed} {m:?}@{b}: space {space_bytes} vs bank {bank_bytes}"
                );
                let bank_avg_bits = bank_bytes as f64 * 8.0 / total_params as f64;
                assert!(
                    (space.avg_bits(&cfg) - bank_avg_bits).abs() < 1e-9,
                    "seed {seed} {m:?}@{b}: avg_bits {} vs bank {bank_avg_bits}",
                    space.avg_bits(&cfg)
                );
            }
        }
        // per-method bank stats add up to the sum of their pieces
        assert_eq!(
            bank.memory_bytes(),
            bank.stats.iter().map(|s| s.memory_bytes).sum::<usize>()
        );
    }
}

// ---------------------------------------------------------------------------
// Pareto / NSGA-II invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_pareto_front_is_mutually_non_dominating_and_complete() {
    for seed in 0..TRIALS as u64 {
        let mut rng = Rng::new(3000 + seed);
        let n = rng.range(2, 60);
        let pts: Vec<(f64, f64)> = (0..n).map(|_| (rng.f64(), rng.f64())).collect();
        let front = pareto_front_of(&pts);
        assert!(!front.is_empty(), "seed {seed}");
        // no front point dominated by any point
        for &i in &front {
            for (j, q) in pts.iter().enumerate() {
                if j == i {
                    continue;
                }
                let dominated = q.0 <= pts[i].0 && q.1 <= pts[i].1
                    && (q.0 < pts[i].0 || q.1 < pts[i].1);
                assert!(!dominated, "seed {seed}: front point {i} dominated by {j}");
            }
        }
        // every non-front point is dominated by some point
        for (j, q) in pts.iter().enumerate() {
            if front.contains(&j) {
                continue;
            }
            let dominated = pts.iter().enumerate().any(|(i, p)| {
                i != j && p.0 <= q.0 && p.1 <= q.1 && (p.0 < q.0 || p.1 < q.1)
            });
            assert!(dominated, "seed {seed}: point {j} not on front yet undominated");
        }
    }
}

#[test]
fn prop_non_dominated_sort_ranks_consistent_with_domination() {
    for seed in 0..TRIALS as u64 {
        let mut rng = Rng::new(4000 + seed);
        let n = rng.range(3, 40);
        let mut pop: Vec<Individual> = (0..n)
            .map(|_| Individual {
                config: vec![],
                obj: [rng.f64(), rng.f64()],
                rank: 0,
                crowding: 0.0,
            })
            .collect();
        nsga2::non_dominated_sort(&mut pop);
        for i in 0..n {
            for j in 0..n {
                if dominates(&pop[i].obj, &pop[j].obj) {
                    assert!(
                        pop[i].rank < pop[j].rank,
                        "seed {seed}: dominator rank {} !< {}",
                        pop[i].rank,
                        pop[j].rank
                    );
                }
            }
        }
    }
}

#[test]
fn prop_nsga2_population_stays_in_space() {
    for seed in 0..12u64 {
        let mut rng = Rng::new(5000 + seed);
        let space = rand_space(&mut rng);
        let pop = nsga2::run(
            &space,
            vec![],
            &nsga2::Nsga2Params {
                pop_size: 24,
                generations: 6,
                crossover_prob: 0.9,
                mutation_prob: 0.2,
            },
            &mut rng,
            |cfg| [cfg.iter().map(|&b| b as f64).sum(), space.avg_bits(cfg)],
        );
        for ind in &pop {
            assert!(space.contains(&ind.config), "seed {seed}");
        }
    }
}

// ---------------------------------------------------------------------------
// Archive invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_archive_best_under_is_feasible_and_optimal() {
    for seed in 0..TRIALS as u64 {
        let mut rng = Rng::new(6000 + seed);
        let mut archive = Archive::new();
        let space = rand_space(&mut rng);
        for _ in 0..rng.range(5, 80) {
            let cfg = space.random(&mut rng);
            let bits = space.avg_bits(&cfg);
            archive.insert(cfg, rng.f32(), bits);
        }
        let budget = 2.0 + 2.5 * rng.f64();
        if let Some(best) = archive.best_under(budget, 0.005) {
            assert!(best.avg_bits <= budget + 0.005, "seed {seed}");
            for s in &archive.samples {
                if s.avg_bits <= budget + 0.005 {
                    assert!(best.jsd <= s.jsd, "seed {seed}: not minimal");
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Quantizer invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_pack_roundtrip_random_shapes() {
    for seed in 0..TRIALS as u64 {
        let mut rng = Rng::new(7000 + seed);
        let bits = [2u8, 3, 4, 8][rng.below(4)];
        let n = rng.range(1, 3000);
        let codes: Vec<u8> = (0..n).map(|_| rng.below(1 << bits) as u8).collect();
        let packed = pack::pack(&codes, bits);
        assert_eq!(packed.len(), pack::packed_bytes(n, bits), "seed {seed}");
        assert_eq!(pack::unpack(&packed, bits, n), codes, "seed {seed}");
    }
}

#[test]
fn prop_quantizers_error_monotone_in_bits() {
    for seed in 0..20u64 {
        let mut rng = Rng::new(8000 + seed);
        let n = 8 * rng.range(1, 5);
        let k = 64 * rng.range(1, 4);
        let w = rand_mat(&mut rng, n, k);
        for q in [&Rtn as &dyn Quantizer, &Hqq::default() as &dyn Quantizer] {
            let e2 = frob_error(&w, &q.quantize(&w, 2, 64, None));
            let e4 = frob_error(&w, &q.quantize(&w, 4, 64, None));
            assert!(e4 < e2, "seed {seed} {}: e4 {e4} !< e2 {e2}", q.name());
        }
    }
}

#[test]
fn prop_dequant_matches_manual_reconstruction() {
    for seed in 0..TRIALS as u64 {
        let mut rng = Rng::new(9000 + seed);
        let n = rng.range(1, 12);
        let gs = 32;
        let g = rng.range(1, 4);
        let k = gs * g;
        let w = rand_mat(&mut rng, n, k);
        let q = Rtn.quantize(&w, 3, gs, None);
        let dq = q.dequant();
        for _ in 0..10 {
            let o = rng.below(n);
            let j = rng.below(k);
            let gi = j / gs;
            let expect = (q.codes[o * k + j] as f32 - q.zero[o * g + gi])
                * q.scale[o * g + gi];
            assert!((dq[(o, j)] - expect).abs() < 1e-6, "seed {seed}");
        }
    }
}

#[test]
fn prop_group_metadata_overhead_accounting() {
    // bits_per_weight = bits + 32/gs exactly, for any geometry
    for seed in 0..TRIALS as u64 {
        let mut rng = Rng::new(10_000 + seed);
        let gs = [32usize, 64, 128][rng.below(3)];
        let g = rng.range(1, 5);
        let (n, k) = (8, gs * g);
        let w = rand_mat(&mut rng, n, k);
        let bits = [2u8, 3, 4][rng.below(3)];
        let q = Rtn.quantize(&w, bits, gs, None);
        let want = bits as f64 + 32.0 / gs as f64;
        assert!((q.bits_per_weight() - want).abs() < 1e-12, "seed {seed}");
    }
}

// ---------------------------------------------------------------------------
// Lane-slab packing / slab-cache invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_pack_lane_slab_roundtrip() {
    // any (lanes, rows, row length): non-padded lanes are bit-equal to
    // their inputs, and the padded region is exactly lane 0's bytes
    for seed in 0..TRIALS as u64 {
        let mut rng = Rng::new(14_000 + seed);
        let lanes = rng.range(1, 9);
        let n_rows = rng.range(1, lanes + 1);
        let per = rng.range(1, 200);
        // u8 payload (the codes path)
        let rows_u8: Vec<Vec<u8>> = (0..n_rows)
            .map(|_| (0..per).map(|_| rng.below(16) as u8).collect())
            .collect();
        let refs: Vec<&[u8]> = rows_u8.iter().map(|r| r.as_slice()).collect();
        let slab = pack_lane_slab(&refs, lanes).unwrap();
        assert_eq!(slab.len(), lanes * per, "seed {seed}");
        for lane in 0..lanes {
            let got = &slab[lane * per..(lane + 1) * per];
            let want: &[u8] = if lane < n_rows { &rows_u8[lane] } else { &rows_u8[0] };
            assert_eq!(got, want, "seed {seed} lane {lane}");
        }
        // f32 payload (the scale/zero path): bit-level equality
        let rows_f: Vec<Vec<f32>> = (0..n_rows)
            .map(|_| (0..per).map(|_| rng.normal() * 0.3).collect())
            .collect();
        let refs: Vec<&[f32]> = rows_f.iter().map(|r| r.as_slice()).collect();
        let slab = pack_lane_slab(&refs, lanes).unwrap();
        for lane in 0..lanes {
            let want: &[f32] = if lane < n_rows { &rows_f[lane] } else { &rows_f[0] };
            for (a, b) in slab[lane * per..(lane + 1) * per].iter().zip(want) {
                assert_eq!(a.to_bits(), b.to_bits(), "seed {seed} lane {lane}");
            }
        }
    }
}

/// Deterministic synthetic score, seeded purely from the config.
fn slab_synth(cfg: &Config) -> f32 {
    let mut seed = 0x9E37_79B9_7F4A_7C15u64;
    for &g in cfg {
        seed = seed.wrapping_mul(0x1000_0000_01B3).wrapping_add(g as u64);
    }
    Rng::new(seed).f32()
}

/// Score a stream of candidate chunks through a simulated lane scheduler
/// whose scores are reconstructed **from the slab contents** (payload =
/// the padded signature, exactly what the packed bytes encode), so any
/// stale or miskeyed cache entry corrupts the output.  Mirrors the
/// production shape: the plan is resolved once per chunk, then replayed
/// across `batches` calibration batches.
fn score_stream(
    chunks: &[Vec<Config>],
    n_layers: usize,
    lanes: usize,
    budget: usize,
    batches: usize,
) -> Vec<f32> {
    let cache: SlabCache<Vec<u16>> = SlabCache::new(budget);
    let mut out = Vec::new();
    for chunk in chunks {
        if lane_routed(chunk.len(), lanes) {
            let mut plan: Vec<(usize, Vec<Arc<Vec<u16>>>)> = Vec::new();
            for group in chunk.chunks(lanes) {
                let mut slabs = Vec::with_capacity(n_layers);
                for li in 0..n_layers {
                    let sig = lane_slab_sig(group, li, lanes);
                    let bytes = 64 + 8 * li; // deterministic per-key size
                    let slab = cache
                        .get_or_build((li, sig.clone()), || Ok((sig.clone(), bytes)))
                        .unwrap();
                    slabs.push(slab);
                }
                plan.push((group.len(), slabs));
            }
            let mut sums = vec![0.0f64; chunk.len()];
            for _ in 0..batches {
                let mut idx = 0;
                for (real, slabs) in &plan {
                    for j in 0..*real {
                        let cfg: Config = (0..n_layers).map(|li| slabs[li][j]).collect();
                        sums[idx] += slab_synth(&cfg) as f64;
                        idx += 1;
                    }
                }
            }
            out.extend(sums.into_iter().map(|s| (s / batches as f64) as f32));
        } else {
            for cfg in chunk {
                let mut sum = 0.0f64;
                for _ in 0..batches {
                    sum += slab_synth(cfg) as f64;
                }
                out.push((sum / batches as f64) as f32);
            }
        }
        // accounting invariant on every step: the cache never exceeds its
        // budget, and budget 0 retains nothing
        let s = cache.stats();
        assert!(s.resident_bytes <= budget, "cache exceeded budget");
        if budget == 0 {
            assert_eq!(s.resident_slabs, 0);
        }
    }
    out
}

#[test]
fn prop_slab_cache_never_changes_scores() {
    // random candidate streams: cache off (budget 0), tiny (constant
    // eviction) and ample budgets must produce bit-identical scores — the
    // cache may only change how often slabs are packed
    for seed in 0..TRIALS as u64 {
        let mut rng = Rng::new(15_000 + seed);
        let n_layers = rng.range(1, 6);
        let lanes = [2usize, 4, 8][rng.below(3)];
        let batches = rng.range(1, 4);
        let n_chunks = rng.range(2, 10);
        let chunks: Vec<Vec<Config>> = (0..n_chunks)
            .map(|_| {
                (0..rng.range(1, 11))
                    .map(|_| (0..n_layers).map(|_| [2u16, 3, 4][rng.below(3)]).collect())
                    .collect()
            })
            .collect();
        let off = score_stream(&chunks, n_layers, lanes, 0, batches);
        let tiny = score_stream(&chunks, n_layers, lanes, 80, batches);
        let ample = score_stream(&chunks, n_layers, lanes, 1 << 20, batches);
        let bits = |v: &[f32]| -> Vec<u32> { v.iter().map(|x| x.to_bits()).collect() };
        assert_eq!(bits(&off), bits(&tiny), "seed {seed}: tiny budget changed scores");
        assert_eq!(bits(&off), bits(&ample), "seed {seed}: ample budget changed scores");
    }
}

// ---------------------------------------------------------------------------
// Eval-pool fault / hedging invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_faulted_pool_delivers_exactly_once_in_order() {
    // Random fault plans crossed with random chunk schedules and hedging
    // on/off: every reply is delivered exactly once, `call_batch` never
    // reorders or drops a chunk, and the copy-conservation identity holds
    // at quiescence — hedged and requeued duplicates are discarded by
    // chunk id, never double-delivered.
    for seed in 0..TRIALS as u64 {
        let mut rng = Rng::new(16_000 + seed);
        let shards = rng.range(2, 5);
        let hedge = if rng.below(2) == 0 {
            HedgePolicy::disabled()
        } else {
            HedgePolicy::from_factor(4.0)
        };
        // Every shard except the last may carry a seeded fault plan; the
        // last stays clean so the pool always has a path to progress.
        let mut plans: Vec<Option<Arc<FaultPlan>>> = Vec::new();
        for s in 0..shards - 1 {
            if rng.below(2) == 0 {
                plans.push(None);
                continue;
            }
            let kind = if hedge.enabled() {
                [FaultKind::Delay, FaultKind::Drop, FaultKind::Wedge][rng.below(3)]
            } else {
                // A wedge with hedging off would hang forever: in-process
                // shards have no chunk-timeout machinery by design.
                [FaultKind::Delay, FaultKind::Drop][rng.below(2)]
            };
            let rate = [0.3, 1.0][rng.below(2)];
            let spec = FaultSpec { seed: 40_000 + seed * 8 + s as u64, kind, rate };
            let mut plan = FaultPlan::new(spec).with_delay(Duration::from_millis(1));
            if rng.below(2) == 0 {
                plan = plan.with_max_faults(1 + rng.below(2) as u64);
            }
            plans.push(Some(Arc::new(plan)));
        }
        plans.push(None);
        let labels: Vec<String> = (0..shards).map(|i| format!("local#{i}")).collect();
        let builder_plans = plans.clone();
        let builder = move |shard: usize| {
            let inner: Box<dyn FnMut(Vec<Config>) -> ShardFlow<amq::Result<Vec<f32>>>> =
                Box::new(move |chunk: Vec<Config>| ShardFlow::Reply(synth_chunk(&chunk)));
            match &builder_plans[shard] {
                Some(plan) => plan.wrap_flow(inner),
                None => inner,
            }
        };
        let svc: Arc<EvalPool> = Arc::new(EvalService::spawn_flow_with(labels, builder, hedge));
        let mut total_chunks = 0u64;
        for _ in 0..rng.range(1, 4) {
            let batch: Vec<Vec<Config>> = (0..rng.range(2, 7))
                .map(|_| {
                    (0..rng.range(1, 4))
                        .map(|_| (0..12).map(|_| [2u16, 3, 4][rng.below(3)]).collect())
                        .collect()
                })
                .collect();
            total_chunks += batch.len() as u64;
            let got = svc.call_batch(batch.clone()).unwrap();
            assert_eq!(got.len(), batch.len(), "seed {seed}: replies dropped");
            for (i, (reply, chunk)) in got.into_iter().zip(batch.iter()).enumerate() {
                let want = synth_chunk(chunk).unwrap();
                let scores = reply
                    .unwrap_or_else(|e| panic!("seed {seed}: chunk {i} errored: {e}"));
                assert_eq!(scores, want, "seed {seed}: chunk {i} reordered or corrupted");
            }
        }
        // Open any wedge gates and wait for every in-flight copy to
        // resolve, so the final accounting is quiescent.
        for plan in plans.iter().flatten() {
            plan.release_wedges();
        }
        let deadline = Instant::now() + Duration::from_secs(10);
        while svc.in_flight() > 0 {
            assert!(Instant::now() < deadline, "seed {seed}: pool failed to drain");
            std::thread::sleep(Duration::from_millis(1));
        }
        let stats = svc.stats();
        assert_eq!(stats.submitted, total_chunks, "seed {seed}");
        assert_eq!(
            stats.completed, total_chunks,
            "seed {seed}: exactly-once delivery broken: {stats:?}"
        );
        assert_eq!(
            stats.completed,
            stats.dispatched - stats.hedged_wasted - stats.requeued_duplicates,
            "seed {seed}: copy conservation violated: {stats:?}"
        );
    }
}

//! Cross-process topology contract: the archive a seeded search produces
//! must be byte-identical whether candidates were scored in-process,
//! across loopback TCP shards, or both at once — and a shard dying
//! mid-search must degrade throughput, never results.
//!
//! CI runs this suite single-threaded (`--test-threads=1`) so loopback
//! servers never contend for ports or CPU with sibling tests.

use amq::coordinator::synth::{synth_chunk, synth_space};
use amq::coordinator::{run_search, try_gene_method, Config, EvalPool, PooledEvaluator, SearchParams};
use amq::runtime::remote::{remote_eval_flow, spawn_test_server, RemoteShard, RetryPolicy};
use amq::runtime::{EvalService, ServiceStats, ShardFlow};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

fn seeded_params() -> SearchParams {
    let mut p = SearchParams::smoke();
    p.seed = 17;
    p
}

/// Reconnect quickly so the killed-shard test converges in milliseconds
/// instead of the production backoff schedule.
fn fast_retry() -> RetryPolicy {
    RetryPolicy {
        attempts: 2,
        base_delay: Duration::from_millis(1),
        max_delay: Duration::from_millis(5),
    }
}

/// Run the seeded synthetic search against `svc` and report the archive
/// content hash plus the pool's view of how the work went.
fn search_hash(svc: Arc<EvalPool>) -> (u64, ServiceStats) {
    let space = synth_space(12);
    let mut ev = PooledEvaluator::from_service(svc).with_score_batch(8);
    let res = run_search(&space, &mut ev, &seeded_params()).unwrap();
    (res.archive.content_hash(), ev.pool_stats())
}

fn local_pool(workers: usize) -> Arc<EvalPool> {
    Arc::new(EvalService::spawn_sharded(workers, |_shard| {
        |chunk: Vec<Config>| -> amq::Result<Vec<f32>> { synth_chunk(&chunk) }
    }))
}

/// `local` in-process shards plus one feeder per remote address, all
/// work-sharing the same FIFO — the same wiring `repro search --shards`
/// builds.
fn mixed_pool(local: usize, remotes: Vec<String>, policy: RetryPolicy) -> Arc<EvalPool> {
    let labels: Vec<String> = (0..local)
        .map(|i| format!("local#{i}"))
        .chain(remotes.iter().cloned())
        .collect();
    Arc::new(EvalService::spawn_flow(labels, move |shard| {
        if shard < local {
            Box::new(move |chunk: Vec<Config>| ShardFlow::Reply(synth_chunk(&chunk)))
        } else {
            remote_eval_flow(remotes[shard - local].clone(), policy)
        }
    }))
}

fn synth_server() -> String {
    spawn_test_server(0, None, synth_chunk).unwrap()
}

#[test]
fn archives_byte_identical_across_topologies() {
    // The four topologies of the CI matrix: sequential, threaded,
    // remote-only over two loopback shards, and mixed local+remote.
    let (sequential, _) = search_hash(local_pool(1));
    let (threaded, _) = search_hash(local_pool(4));

    let remotes = vec![synth_server(), synth_server()];
    let (remote, rstats) = search_hash(mixed_pool(0, remotes.clone(), RetryPolicy::default()));
    let (mixed, mstats) = search_hash(mixed_pool(2, remotes, RetryPolicy::default()));

    assert_eq!(
        sequential, threaded,
        "threaded archive diverged from sequential"
    );
    assert_eq!(
        sequential, remote,
        "remote-shard archive diverged from sequential"
    );
    assert_eq!(sequential, mixed, "mixed archive diverged from sequential");

    // Sanity on the pool's own accounting: nothing retired, nothing
    // requeued, and the remote run really did flow through remote shards.
    assert_eq!(rstats.retired_shards(), 0);
    assert_eq!(rstats.requeued, 0);
    assert_eq!(mstats.retired_shards(), 0);
    assert!(
        rstats.per_shard.iter().any(|s| s.completed > 0),
        "remote shards served no chunks"
    );
}

#[test]
fn killed_shard_mid_search_converges_to_identical_archive() {
    let (baseline, _) = search_hash(local_pool(1));

    // Shard B's process "dies" after three chunks: the eval panics, which
    // kills the detached server thread, drops its listener, and resets the
    // in-flight connection.  The client must retire that feeder, requeue
    // the chunk it was carrying, and finish on the surviving shard.
    let healthy = synth_server();
    let served = Arc::new(AtomicUsize::new(0));
    let served_by_victim = served.clone();
    let victim = spawn_test_server(0, None, move |genes: &[Vec<u16>]| {
        if served_by_victim.fetch_add(1, Ordering::SeqCst) >= 3 {
            panic!("injected shard death");
        }
        synth_chunk(genes)
    })
    .unwrap();

    let (hash, stats) = search_hash(mixed_pool(0, vec![healthy, victim], fast_retry()));
    assert_eq!(
        baseline, hash,
        "archive diverged after a shard died mid-search"
    );
    assert_eq!(stats.retired_shards(), 1, "exactly the victim should retire");
    assert!(
        stats.requeued >= 1,
        "the in-flight chunk must be requeued, not lost"
    );
    let victim_stats = stats.per_shard.iter().find(|s| s.retired).unwrap();
    assert!(victim_stats.completed >= 1, "victim served before dying");
}

#[test]
fn corrupt_gene_gets_wire_error_and_server_keeps_serving() {
    // A client feeding garbage genes (method nibble outside MethodId::ALL)
    // must get a clean wire Error frame naming the bad byte — not a server
    // panic — and the same connection must keep answering valid chunks.
    let addr = spawn_test_server(0, None, |genes: &[Vec<u16>]| {
        for g in genes.iter().flatten() {
            if try_gene_method(*g).is_none() {
                eyre::bail!("invalid method byte in gene {g:#06x}");
            }
        }
        synth_chunk(genes)
    })
    .unwrap();

    let mut shard = RemoteShard::new(addr, fast_retry());

    let bad = vec![vec![0x0F03u16; 12]];
    let msg = shard.call(&bad).unwrap().unwrap_err();
    assert!(
        msg.contains("invalid method byte"),
        "wire error should name the corrupt gene, got: {msg}"
    );

    // The connection survived the error frame: valid work still flows and
    // matches the in-process oracle exactly.
    let good = vec![vec![3u16; 12], vec![2u16; 12]];
    let scores = shard.call(&good).unwrap().unwrap();
    assert_eq!(scores, synth_chunk(&good).unwrap());

    // And a second corrupt chunk is still answered cleanly, not fatally.
    let msg2 = shard.call(&bad).unwrap().unwrap_err();
    assert!(msg2.contains("invalid method byte"));
}

#[test]
fn all_shards_dead_is_an_error_not_a_hang() {
    // Both feeders point at nothing: bind-then-drop reserves addresses
    // that refuse connections.  Every call must error out (bounded
    // retries), never block forever or panic.
    let dead_addr = || {
        let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        l.local_addr().unwrap().to_string()
    };
    let svc = mixed_pool(0, vec![dead_addr(), dead_addr()], fast_retry());
    let err = svc
        .call_batch(vec![vec![vec![2u16; 12]], vec![vec![4u16; 12]]])
        .unwrap_err();
    let msg = format!("{err}");
    assert!(
        msg.contains("retired"),
        "error should name the retired shards, got: {msg}"
    );
    assert_eq!(svc.live_workers(), 0);
}

//! Continuous-batching serve-path tests.
//!
//! The load-bearing property: the [`ContinuousBatcher`] must return scores
//! bitwise identical to the sequential single-candidate path for *any*
//! arrival interleaving — batching is a throughput optimization, never an
//! accuracy knob.  The synthetic evaluator (`synth_chunk`, a pure
//! per-candidate map of `synth_jsd`) makes that checkable without a device:
//! whatever slabs the scheduler forms, each candidate's score only depends
//! on its own genes.
//!
//! Alongside the property test: the deadline-policy contracts (partial slab
//! flushes at the deadline, a full slab never waits for it, queued work
//! drains on shutdown), the batching acceptance pin (`dispatches <
//! requests` under a lane-filling workload), and an end-to-end TCP
//! round-trip through `serve_scores` / `ScoreClient` / `fetch_serve_stats`.

use std::net::TcpListener;
use std::sync::mpsc::Receiver;
use std::time::{Duration, Instant};

use amq::coordinator::synth::{synth_chunk, synth_jsd};
use amq::runtime::serve::{
    fetch_serve_stats, serve_scores, ScoreClient, ScoreResult, ServeOptions,
};
use amq::runtime::{ContinuousBatcher, SchedulerOptions, SchedulerStats};
use amq::util::Rng;

const TRIALS: usize = 60;

fn spawn_synth(opts: SchedulerOptions) -> ContinuousBatcher {
    ContinuousBatcher::spawn(opts, || synth_chunk)
}

fn random_genes(rng: &mut Rng) -> Vec<u16> {
    let n = rng.range(1, 24);
    (0..n).map(|_| rng.range(2, 5) as u16).collect()
}

fn expect_score(rx: &Receiver<ScoreResult>) -> f32 {
    rx.recv_timeout(Duration::from_secs(30))
        .expect("batcher dropped the reply channel")
        .expect("batcher returned an error")
}

/// Property: for random lanes / deadlines / request counts and a random
/// multi-threaded arrival interleaving, every score the batcher returns is
/// bitwise identical to the sequential scorer (`synth_jsd` on that
/// candidate alone).  Slab composition must not leak into the numbers.
#[test]
fn any_arrival_interleaving_matches_the_sequential_scorer_bitwise() {
    for seed in 0..TRIALS as u64 {
        let mut rng = Rng::new(0x5E27E + seed);
        let opts = SchedulerOptions {
            lanes: rng.range(1, 9),
            max_wait: Duration::from_micros(rng.range(0, 800) as u64),
            queue_cap: 1024,
        };
        let batcher = spawn_synth(opts);
        let n_threads = rng.range(1, 5);
        let per_thread = rng.range(1, 12);
        let mut expected: Vec<Vec<(Vec<u16>, u32)>> = Vec::new();
        for t in 0..n_threads {
            let mut lane = Vec::new();
            let mut trng = Rng::new(seed * 131 + t as u64);
            for _ in 0..per_thread {
                let genes = random_genes(&mut trng);
                let bits = synth_jsd(&genes).to_bits();
                lane.push((genes, bits));
            }
            expected.push(lane);
        }
        let results: Vec<Vec<(u32, u32)>> = std::thread::scope(|scope| {
            let handles: Vec<_> = expected
                .iter()
                .enumerate()
                .map(|(t, lane)| {
                    let batcher = &batcher;
                    scope.spawn(move || {
                        let mut srng = Rng::new(seed * 977 + t as u64);
                        let mut out = Vec::new();
                        for (genes, bits) in lane {
                            // Random inter-arrival jitter: this is the
                            // "any interleaving" part of the property.
                            std::thread::sleep(Duration::from_micros(
                                srng.range(0, 300) as u64,
                            ));
                            let got = batcher
                                .score(genes.clone())
                                .expect("score failed")
                                .to_bits();
                            out.push((got, *bits));
                        }
                        out
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for lane in &results {
            for &(got, want) in lane {
                assert_eq!(
                    got, want,
                    "seed {seed}: batched score {got:#010x} != sequential {want:#010x}"
                );
            }
        }
        let stats = batcher.stats();
        assert_eq!(stats.requests, (n_threads * per_thread) as u64, "seed {seed}");
        assert_eq!(stats.batched, stats.requests, "seed {seed}: every request dispatched");
        assert_eq!(stats.rejected, 0, "seed {seed}");
    }
}

/// A partial slab (fewer queued requests than lanes) must flush when the
/// oldest request's deadline expires — not wait for the slab to fill.
#[test]
fn partial_slab_dispatches_at_the_deadline() {
    let batcher = spawn_synth(SchedulerOptions {
        lanes: 4,
        max_wait: Duration::from_millis(20),
        queue_cap: 64,
    });
    let a = batcher.submit(vec![2, 3, 4]);
    let b = batcher.submit(vec![4, 3, 2]);
    let start = Instant::now();
    let sa = expect_score(&a);
    let sb = expect_score(&b);
    assert!(
        start.elapsed() < Duration::from_secs(10),
        "deadline flush took {:?}",
        start.elapsed()
    );
    assert_eq!(sa.to_bits(), synth_jsd(&[2, 3, 4]).to_bits());
    assert_eq!(sb.to_bits(), synth_jsd(&[4, 3, 2]).to_bits());
    let stats = batcher.stats();
    assert_eq!(stats.full_dispatches, 0, "2 requests can't fill 4 lanes");
    assert!(stats.deadline_dispatches >= 1, "stats: {stats:?}");
    assert_eq!(stats.batched, 2);
}

/// A full slab dispatches immediately: with a deadline far beyond the test
/// timeout, `lanes` queued requests must still complete promptly.
#[test]
fn full_slab_dispatches_without_waiting_for_the_deadline() {
    let lanes = 3;
    let batcher = spawn_synth(SchedulerOptions {
        lanes,
        max_wait: Duration::from_secs(3600),
        queue_cap: 64,
    });
    let rxs: Vec<_> = (0..lanes)
        .map(|i| batcher.submit(vec![2 + i as u16; 6]))
        .collect();
    let start = Instant::now();
    for (i, rx) in rxs.iter().enumerate() {
        let got = expect_score(rx);
        assert_eq!(got.to_bits(), synth_jsd(&vec![2 + i as u16; 6]).to_bits());
    }
    assert!(
        start.elapsed() < Duration::from_secs(30),
        "full slab waited on a 1h deadline"
    );
    let stats = batcher.stats();
    assert_eq!(stats.full_dispatches, 1, "stats: {stats:?}");
    assert_eq!(stats.deadline_dispatches, 0, "stats: {stats:?}");
    assert_eq!(stats.batched, lanes as u64);
}

/// Shutdown drains: requests queued behind an hour-long deadline still get
/// answers when the batcher shuts down, via drain dispatches.
#[test]
fn queued_requests_drain_on_shutdown() {
    let mut batcher = spawn_synth(SchedulerOptions {
        lanes: 8,
        max_wait: Duration::from_secs(3600),
        queue_cap: 64,
    });
    let genes: Vec<Vec<u16>> = (0..3).map(|i| vec![2 + (i % 3) as u16; 5]).collect();
    let rxs: Vec<_> = genes.iter().map(|g| batcher.submit(g.clone())).collect();
    batcher.shutdown();
    for (g, rx) in genes.iter().zip(&rxs) {
        let got = rx
            .recv_timeout(Duration::from_secs(5))
            .expect("drained request lost its reply channel")
            .expect("drained request errored");
        assert_eq!(got.to_bits(), synth_jsd(g).to_bits());
    }
    let stats = batcher.stats();
    assert_eq!(stats.batched, 3, "stats: {stats:?}");
    assert!(stats.drain_dispatches() >= 1, "stats: {stats:?}");
    // Post-shutdown submissions reject, and the reply path still works.
    let late = batcher.score(vec![2, 2, 2]);
    assert!(late.unwrap_err().contains("shut down"));
}

/// Acceptance pin: a lane-filling concurrent workload must coalesce — the
/// whole point of the scheduler is fewer device dispatches than requests.
#[test]
fn full_lane_workload_takes_fewer_dispatches_than_requests() {
    let batcher = spawn_synth(SchedulerOptions {
        lanes: 8,
        max_wait: Duration::from_millis(5),
        queue_cap: 1024,
    });
    let threads = 8;
    let per_thread = 8;
    std::thread::scope(|scope| {
        for t in 0..threads {
            let batcher = &batcher;
            scope.spawn(move || {
                for i in 0..per_thread {
                    let genes = vec![2 + ((t + i) % 3) as u16; 6];
                    let got = batcher.score(genes.clone()).expect("score failed");
                    assert_eq!(got.to_bits(), synth_jsd(&genes).to_bits());
                }
            });
        }
    });
    let stats = batcher.stats();
    assert_eq!(stats.requests, (threads * per_thread) as u64);
    assert_eq!(stats.batched, stats.requests);
    assert!(
        stats.dispatches < stats.requests,
        "no coalescing happened: {stats:?}"
    );
    assert!(stats.lane_fill_fraction() > 0.0 && stats.lane_fill_fraction() <= 1.0);
}

fn recv_stats(rx: Receiver<SchedulerStats>) -> SchedulerStats {
    rx.recv_timeout(Duration::from_secs(60)).expect("serve thread died")
}

/// End-to-end over TCP: two concurrent `ScoreClient`s (one sending explicit
/// genes, one leaning on the server's default config), then a stats probe,
/// all against one `serve_scores` loop.  Scores must match the sequential
/// scorer bitwise and the probe must see every request.
#[test]
fn serve_scores_round_trips_clients_and_stats_over_tcp() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let default_genes = vec![3u16; 12];
    let opts = ServeOptions {
        scheduler: SchedulerOptions {
            lanes: 4,
            max_wait: Duration::from_millis(2),
            queue_cap: 256,
        },
        max_conns: Some(3), // two score clients + one stats probe
        live_cap: 8,
        default_genes: Some(default_genes.clone()),
    };
    let (done_tx, done_rx) = std::sync::mpsc::channel();
    std::thread::spawn(move || {
        let stats = serve_scores(listener, 12, opts, || synth_chunk).unwrap();
        let _ = done_tx.send(stats);
    });

    let timeout = Duration::from_secs(10);
    let explicit = {
        let addr = addr.clone();
        std::thread::spawn(move || {
            let mut client = ScoreClient::connect(&addr, timeout).unwrap();
            assert_eq!(client.n_layers(), 12);
            let mut out = Vec::new();
            for i in 0..5u16 {
                let genes = vec![2 + i % 3; 9];
                let got = client.score(&genes).unwrap().unwrap();
                out.push((got.to_bits(), synth_jsd(&genes).to_bits()));
            }
            out
        })
    };
    let defaulted = {
        let addr = addr.clone();
        let default_genes = default_genes.clone();
        std::thread::spawn(move || {
            let mut client = ScoreClient::connect(&addr, timeout).unwrap();
            let want = synth_jsd(&default_genes).to_bits();
            (0..5)
                .map(|_| {
                    // Empty genes = "score the config this server serves".
                    let got = client.score(&[]).unwrap().unwrap();
                    (got.to_bits(), want)
                })
                .collect::<Vec<_>>()
        })
    };
    for (got, want) in explicit
        .join()
        .unwrap()
        .into_iter()
        .chain(defaulted.join().unwrap())
    {
        assert_eq!(got, want, "TCP score {got:#010x} != sequential {want:#010x}");
    }

    let probed = fetch_serve_stats(&addr, timeout).unwrap();
    assert_eq!(probed.requests, 10, "probe: {probed:?}");
    assert_eq!(probed.batched, 10);
    assert_eq!(probed.lanes, 4);
    assert_eq!(probed.rejected, 0);

    let final_stats = recv_stats(done_rx);
    assert_eq!(final_stats.requests, 10, "final: {final_stats:?}");
    assert!(final_stats.dispatches >= probed.dispatches);
}

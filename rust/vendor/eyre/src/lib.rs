//! Offline stand-in for the `eyre` crate (API-compatible subset).
//!
//! The build environment has no registry access, so the workspace vendors
//! the exact surface the `amq` crate uses:
//!
//! * [`Report`] — an error value built from a message or any
//!   `std::error::Error`, with `Display`/`Debug` and a source chain;
//! * [`Result<T>`] — `std::result::Result<T, Report>`;
//! * `anyhow!` / `eyre!` — construct a `Report` from a format string;
//! * `bail!` — early-return `Err(anyhow!(...))`;
//! * `ensure!` — `bail!` unless a condition holds (with or without message).
//!
//! To use the real crate instead, delete this directory and point the
//! workspace at crates.io (`eyre = "0.6"`); no call sites change.

use std::error::Error as StdError;
use std::fmt;

/// Error value: a message plus an optional source error.
pub struct Report {
    msg: String,
    source: Option<Box<dyn StdError + Send + Sync + 'static>>,
}

impl Report {
    /// Construct from anything displayable (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Report {
        Report { msg: message.to_string(), source: None }
    }

    /// The root-cause chain, outermost first (empty for message-only reports).
    pub fn chain(&self) -> impl Iterator<Item = &(dyn StdError + 'static)> {
        let mut next = self.source.as_deref().map(|e| e as &(dyn StdError + 'static));
        std::iter::from_fn(move || {
            let cur = next?;
            next = cur.source();
            Some(cur)
        })
    }
}

impl fmt::Display for Report {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Report {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        for (i, cause) in self.chain().enumerate() {
            if i == 0 {
                write!(f, "\n\nCaused by:")?;
            }
            write!(f, "\n    {cause}")?;
        }
        Ok(())
    }
}

// `Report` deliberately does NOT implement `std::error::Error`, which is what
// makes this blanket conversion coherent (mirroring real eyre/anyhow).
impl<E: StdError + Send + Sync + 'static> From<E> for Report {
    fn from(err: E) -> Report {
        Report { msg: err.to_string(), source: Some(Box::new(err)) }
    }
}

/// Crate-style result alias: `eyre::Result<T>`.
pub type Result<T, E = Report> = std::result::Result<T, E>;

/// Construct a [`Report`] from a format string (anyhow-compat spelling).
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => { $crate::Report::msg(format!($($arg)*)) };
}

/// Construct a [`Report`] from a format string (eyre-native spelling).
#[macro_export]
macro_rules! eyre {
    ($($arg:tt)*) => { $crate::Report::msg(format!($($arg)*)) };
}

/// Early-return `Err(Report)` from the enclosing function.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => { return Err($crate::anyhow!($($arg)*)) };
}

/// Early-return unless `cond` holds.  With a single argument the message is
/// the stringified condition (eyre behaviour).
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn needs_two(x: i32) -> Result<i32> {
        ensure!(x == 2, "want 2, got {x}");
        Ok(x * 10)
    }

    fn bare_ensure(x: i32) -> Result<()> {
        ensure!(x > 0);
        Ok(())
    }

    #[test]
    fn ensure_and_bail() {
        assert_eq!(needs_two(2).unwrap(), 20);
        let err = needs_two(3).unwrap_err();
        assert_eq!(err.to_string(), "want 2, got 3");
        assert!(bare_ensure(1).is_ok());
        assert!(bare_ensure(-1).unwrap_err().to_string().contains("x > 0"));
    }

    #[test]
    fn from_std_error_keeps_chain() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let rep: Report = io.into();
        assert_eq!(rep.to_string(), "gone");
        assert_eq!(rep.chain().count(), 1);
        let dbg = format!("{rep:?}");
        assert!(dbg.contains("Caused by"), "{dbg}");
    }

    #[test]
    fn question_mark_converts() {
        fn read() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/file")?;
            Ok(s)
        }
        assert!(read().is_err());
    }
}

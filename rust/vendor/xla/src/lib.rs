//! Compile-only stub of the `xla-rs` PJRT surface consumed by the `amq`
//! crate.
//!
//! The offline build environment has neither the XLA C library nor registry
//! access, so this crate provides the exact types/signatures the runtime
//! layer links against — `PjRtClient`, `PjRtBuffer`, `PjRtLoadedExecutable`,
//! `HloModuleProto`, `XlaComputation`, `Literal` — with a *null backend*:
//! [`PjRtClient::cpu`] returns an error, so no code path past client
//! construction is ever reachable.  Everything that needs a live device
//! (integration tests, end-to-end benches, the `repro` binary) already
//! gates on `amq::artifacts_available()` and skips gracefully.
//!
//! To run against real PJRT, replace this vendored crate with the actual
//! `xla` bindings (same module-level API) via a `[patch]` or by editing
//! `rust/Cargo.toml`; no call sites in `amq` change.

use std::fmt;

/// Backend error type (implements `std::error::Error`, so `?` converts it
/// into `eyre::Report` at call sites).
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable() -> Error {
    Error(
        "PJRT backend unavailable: this is the offline stub crate \
         (swap in the real xla bindings to run on a device)"
            .to_string(),
    )
}

mod sealed {
    pub trait Sealed {}
    impl Sealed for f32 {}
    impl Sealed for f64 {}
    impl Sealed for i8 {}
    impl Sealed for i32 {}
    impl Sealed for i64 {}
    impl Sealed for u8 {}
    impl Sealed for u16 {}
}

/// Element types transferable to/from device buffers.
pub trait ArrayElement: sealed::Sealed + Copy + 'static {}
impl ArrayElement for f32 {}
impl ArrayElement for f64 {}
impl ArrayElement for i8 {}
impl ArrayElement for i32 {}
impl ArrayElement for i64 {}
impl ArrayElement for u8 {}
impl ArrayElement for u16 {}

/// A PJRT client handle.  The stub cannot construct one, which statically
/// guarantees the remaining methods are never reached at runtime.
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    /// Create a CPU client.  Always fails in the stub.
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable())
    }

    /// Upload a host array as a device buffer.
    pub fn buffer_from_host_buffer<T: ArrayElement>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(unavailable())
    }

    /// Compile a computation into a loaded executable.
    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable())
    }
}

/// Parsed HLO module (text format).
pub struct HloModuleProto {
    _priv: (),
}

impl HloModuleProto {
    /// Parse an HLO-text file.  Always fails in the stub.
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable())
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _priv: () }
    }
}

/// A device-resident buffer.
pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable())
    }
}

/// A compiled, device-loaded executable.
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    /// Execute with borrowed argument buffers; returns per-device, per-output
    /// result buffers (`out[device][output]`).
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }
}

/// A host-side literal (result of a device→host transfer).
pub struct Literal {
    _priv: (),
}

impl Literal {
    pub fn to_tuple1(self) -> Result<Literal> {
        Err(unavailable())
    }

    pub fn to_tuple2(self) -> Result<(Literal, Literal)> {
        Err(unavailable())
    }

    pub fn to_vec<T: ArrayElement>(&self) -> Result<Vec<T>> {
        Err(unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn null_backend_refuses_client() {
        let err = PjRtClient::cpu().err().expect("stub must not create clients");
        assert!(err.to_string().contains("offline stub"));
    }

    #[test]
    fn error_converts_via_question_mark() {
        fn through_eyre_like() -> std::result::Result<(), Box<dyn std::error::Error>> {
            let _client = PjRtClient::cpu()?;
            Ok(())
        }
        assert!(through_eyre_like().is_err());
    }
}
